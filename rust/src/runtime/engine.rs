//! The PJRT engine: compile HLO-text artifacts, execute them on the hot
//! path, and adapt the step artifact to the [`Stepper`] trait.
//!
//! [`Stepper`]: crate::sumo::Stepper
//!
//! The `*_into` variants are the hot-path entry points: they fill a
//! caller-owned [`StepOutputs`] instead of minting a fresh one per call.
//! [`Engine::step_batched_into`] refills right-sized per-lane buffers in
//! place (zero allocation in steady state); [`Engine::step_into`] swaps
//! in the PJRT result vectors, whose allocation at the FFI boundary
//! (`Literal` staging / `to_vec`) the vendored `xla` crate does not let
//! us avoid (EXPERIMENTS.md §Perf).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use crate::sumo::state::{GeometryVec, GEOM_COLS, OBS_COLS, PARAM_COLS, STATE_COLS};
use crate::sumo::DEP_COLS;
use crate::telemetry::{self, metrics, metrics::Histogram, EventKind};
use crate::{Error, Result};

use super::manifest::Manifest;
use super::pool::ExecutablePool;

/// The outputs of one AOT step execution.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StepOutputs {
    /// f32[N*4] — next state rows.
    pub state: Vec<f32>,
    /// f32[N] — accelerations.
    pub accel: Vec<f32>,
    /// f32[N*2] — radar returns.
    pub radar: Vec<f32>,
    /// f32[OBS_COLS] — [n_active, mean_speed, flow, n_merged, n_exited].
    pub obs: Vec<f32>,
}

/// The outputs of one fused K-step rollout execution (schema 4): the
/// final state plus the per-step observable trace.  The per-step
/// accel/radar outputs are not part of the rollout ABI — the chunked
/// stepper consumes only state + obs, and dropping them lets XLA
/// dead-code eliminate the radar scan from the loop body.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RolloutOutputs {
    /// f32[N*4] — state rows after the K-th step.
    pub state: Vec<f32>,
    /// f32[K*OBS_COLS] — row i is step i's `[n_active, mean_speed,
    /// flow, n_merged, n_exited]`, bit-identical to K sequential steps.
    pub obs: Vec<f32>,
}

impl RolloutOutputs {
    /// Step i's observable row.
    #[inline]
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * OBS_COLS..(i + 1) * OBS_COLS]
    }

    /// How many fused steps this trace covers.
    #[inline]
    pub fn steps(&self) -> usize {
        self.obs.len() / OBS_COLS
    }
}

/// The outputs of one whole-run execution (schema 5): a T-step run as
/// ONE dispatch, demand compiled in as the departure-table operand.
/// Spawns happen in-kernel, so the params rows are an output too (a
/// spawn writes its driver-params row), and the inserted mask tells the
/// host which table rows made it in — everything it needs to
/// reconstruct its insertion queue for a chunked tail.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RunOutputs {
    /// f32[N*4] — state rows after the T-th step.
    pub state: Vec<f32>,
    /// f32[N*8] — params rows after the T-th step (in-kernel spawns
    /// write them).
    pub params: Vec<f32>,
    /// f32[T*OBS_COLS] — the whole per-step observable trace,
    /// bit-identical to T sequential insert-due-then-step iterations.
    pub obs: Vec<f32>,
    /// f32[D] — 1.0 per departure-table row the kernel inserted.
    pub inserted: Vec<f32>,
}

impl RunOutputs {
    /// Step i's observable row.
    #[inline]
    pub fn obs_row(&self, i: usize) -> &[f32] {
        &self.obs[i * OBS_COLS..(i + 1) * OBS_COLS]
    }

    /// How many steps this run covered.
    #[inline]
    pub fn steps(&self) -> usize {
        self.obs.len() / OBS_COLS
    }
}

/// Clear-and-refill `dst` from `src` — no reallocation once `dst` has
/// grown to the bucket's size.
#[inline]
fn fill(dst: &mut Vec<f32>, src: &[f32]) {
    dst.clear();
    dst.extend_from_slice(src);
}

/// Cached handles into the global telemetry registry for the dispatch
/// latency series (`engine.dispatch.step.latency_us`,
/// `engine.dispatch.rollout_k{K}.latency_us`) — fetched once per
/// engine, so the registry lock never sits on the dispatch path.  The
/// engine lives on one thread (`Rc` client), so a `RefCell` map covers
/// the per-K rollout handles.
struct DispatchMetrics {
    step_latency_us: Arc<Histogram>,
    rollout_latency_us: RefCell<HashMap<usize, Arc<Histogram>>>,
    /// Per-T whole-run series (`engine.dispatch.run_t{T}.latency_us`) —
    /// the schema-5 run kind of the dispatch stream.
    run_latency_us: RefCell<HashMap<usize, Arc<Histogram>>>,
}

impl DispatchMetrics {
    fn new() -> DispatchMetrics {
        DispatchMetrics {
            step_latency_us: metrics::histogram("engine.dispatch.step.latency_us"),
            rollout_latency_us: RefCell::new(HashMap::new()),
            run_latency_us: RefCell::new(HashMap::new()),
        }
    }

    fn rollout(&self, k: usize) -> Arc<Histogram> {
        self.rollout_latency_us
            .borrow_mut()
            .entry(k)
            .or_insert_with(|| {
                metrics::histogram(&format!("engine.dispatch.rollout_k{k}.latency_us"))
            })
            .clone()
    }

    fn run(&self, t: usize) -> Arc<Histogram> {
        self.run_latency_us
            .borrow_mut()
            .entry(t)
            .or_insert_with(|| {
                metrics::histogram(&format!("engine.dispatch.run_t{t}.latency_us"))
            })
            .clone()
    }
}

/// Time one PJRT dispatch into `hist` and, when a telemetry sink is
/// installed, bracket it with `DispatchBegin`/`DispatchEnd` events.
/// Instrumentation stops at dispatch granularity — a fused K-step
/// rollout is ONE sample here, never K (the ≤ 2% hot-path bar).
fn timed<T>(
    hist: &Histogram,
    kind: &'static str,
    bucket: usize,
    k: usize,
    batch: usize,
    f: impl FnOnce() -> Result<T>,
) -> Result<T> {
    let emitting = telemetry::enabled();
    if emitting {
        telemetry::emit(EventKind::DispatchBegin {
            kind: kind.into(),
            bucket: bucket as u64,
            k: k as u64,
            batch: batch as u64,
        });
    }
    let t0 = Instant::now();
    let result = f();
    let dur_us = t0.elapsed().as_micros() as u64;
    hist.record(dur_us);
    if emitting {
        telemetry::emit(EventKind::DispatchEnd {
            kind: kind.into(),
            bucket: bucket as u64,
            k: k as u64,
            batch: batch as u64,
            dur_us,
        });
    }
    result
}

/// The engine: a PJRT CPU client + the artifact manifest + a pool of
/// compiled executables (one per artifact, compiled lazily, shared).
pub struct Engine {
    client: Rc<xla::PjRtClient>,
    manifest: Manifest,
    dir: PathBuf,
    pool: ExecutablePool,
    dispatch: DispatchMetrics,
}

impl Engine {
    /// Construct from an artifacts directory (see
    /// [`super::find_artifacts_dir`]).
    pub fn new(dir: PathBuf) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        manifest.validate_against_default_scenario()?;
        // geometry is a runtime operand and destination intent rides the
        // params row (schema 3): one executable per (kernel, bucket)
        // serves every scenario family and every per-vehicle route, so
        // the engine refuses legacy schema-1/2 artifacts outright —
        // per-column validated, since a drifted column silently
        // scrambles every run
        manifest.validate_geometry_layout()?;
        manifest.validate_param_layout()?;
        // schema 4: fused-rollout entry points, validated when present
        // (schema-3 artifacts still load — single steps only)
        manifest.validate_rollout_layout()?;
        // schema 5: whole-run entry points + the departure-table
        // operand, validated when present (older artifacts still load —
        // the device-resident run path is simply unavailable)
        manifest.validate_departure_layout()?;
        let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
        Ok(Engine {
            client: Rc::new(client),
            manifest,
            dir,
            pool: ExecutablePool::new(),
            dispatch: DispatchMetrics::new(),
        })
    }

    /// Convenience: locate artifacts automatically.
    pub fn auto() -> Result<Engine> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| Error::Artifact("artifacts/ not found; run `make artifacts`".into()))?;
        Engine::new(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Executable-pool hit/miss observability (the compile-amortization
    /// counters nothing read before the PR 3 pass; surfaced in the
    /// campaign summary via `EngineService::pool_usage`).
    pub fn pool_usage(&self) -> crate::metrics::PoolUsage {
        let (hits, misses) = self.pool.stats();
        crate::metrics::PoolUsage {
            hits,
            misses,
            compiled: self.pool.len(),
        }
    }

    /// Compile (or fetch from the pool) the artifact `name_{bucket}`.
    /// Steady state is a read-lock + `Arc` clone — no string keys, no
    /// manifest lookup.
    fn executable(
        &self,
        name: &'static str,
        bucket: usize,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.pool.get_or_compile((name, bucket, 0), || {
            let entry = self.manifest.entry(name, bucket)?;
            self.compile_entry_file(entry)
        })
    }

    /// Compile (or fetch) the fused-rollout artifact `{stem}{k}_{bucket}`
    /// (schema 4).  The K-ladder rung is part of the pool key, so every
    /// (stem, bucket, K) triple compiles exactly once per process.
    fn rollout_executable(
        &self,
        stem: &'static str,
        bucket: usize,
        k: usize,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.pool.get_or_compile((stem, bucket, k), || {
            if !self.manifest.rollouts_available() {
                return Err(Error::Artifact(format!(
                    "artifacts are schema {} with no rollout entry points; \
                     fused rollouts need schema 4 — re-run `make artifacts`",
                    self.manifest.schema
                )));
            }
            let entry = self.manifest.rollout_entry(stem, k, bucket)?;
            self.compile_entry_file(entry)
        })
    }

    /// Compile (or fetch) the whole-run artifact `{stem}{t}_{bucket}`
    /// (schema 5).  The run kind rides the pool key's name slot and the
    /// total-steps rung its K slot, so runs never collide with rollouts
    /// of the same bucket.
    fn run_executable(
        &self,
        stem: &'static str,
        bucket: usize,
        t: usize,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        self.pool.get_or_compile((stem, bucket, t), || {
            if !self.manifest.runs_available() {
                return Err(Error::Artifact(format!(
                    "artifacts are schema {} with no whole-run entry points; \
                     device-resident runs need schema 5 — re-run `make artifacts`",
                    self.manifest.schema
                )));
            }
            let entry = self.manifest.run_entry(stem, t, bucket)?;
            self.compile_entry_file(entry)
        })
    }

    fn compile_entry_file(
        &self,
        entry: &super::manifest::ArtifactEntry,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(Error::runtime)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(Error::runtime)
    }

    fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(Error::runtime)
    }

    /// Execute one full sim step at `bucket` capacity under `geom`.
    pub fn step(
        &self,
        bucket: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
    ) -> Result<StepOutputs> {
        let mut out = StepOutputs::default();
        self.step_into(bucket, state, params, geom, &mut out)?;
        Ok(out)
    }

    /// Execute one full sim step at `bucket` capacity into the caller's
    /// `StepOutputs` (the engine-service hot path).  `geom` is the
    /// scenario geometry operand — the same pooled executable serves any
    /// geometry.  The output `Vec`s are replaced by the PJRT result
    /// vectors (an FFI-boundary allocation the vendored `xla` crate
    /// can't avoid); the batched variant [`Engine::step_batched_into`]
    /// additionally refills per-lane buffers in place.
    pub fn step_into(
        &self,
        bucket: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        out: &mut StepOutputs,
    ) -> Result<()> {
        if state.len() != bucket * STATE_COLS || params.len() != bucket * PARAM_COLS {
            return Err(Error::Runtime(format!(
                "shape mismatch: state {} params {} for bucket {bucket}",
                state.len(),
                params.len()
            )));
        }
        timed(&self.dispatch.step_latency_us, "step", bucket, 0, 1, || {
            self.step_dispatch(bucket, state, params, geom, out)
        })
    }

    fn step_dispatch(
        &self,
        bucket: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        out: &mut StepOutputs,
    ) -> Result<()> {
        let exe = self.executable("step", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let g = xla::Literal::vec1(geom.as_slice());
        let result = exe.execute::<xla::Literal>(&[s, p, g]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ac, ra, ob) = result.to_tuple4().map_err(Error::runtime)?;
        // the xla API only hands data out as fresh Vecs (`to_vec`), so the
        // cheapest correct move is to *swap them in*, not copy them over:
        // one FFI alloc per output either way, zero extra memcpys
        out.state = st.to_vec::<f32>().map_err(Error::runtime)?;
        out.accel = ac.to_vec::<f32>().map_err(Error::runtime)?;
        out.radar = ra.to_vec::<f32>().map_err(Error::runtime)?;
        out.obs = ob.to_vec::<f32>().map_err(Error::runtime)?;
        Ok(())
    }

    /// Execute one sim step for `batch` co-located instances at once via
    /// the vmapped `stepb` artifact — the dynamic micro-batcher of the
    /// engine service (EXPERIMENTS.md §Perf).  `states` is the
    /// concatenation of `batch` state arrays and `geoms` the
    /// concatenation of their per-lane geometry rows (instances running
    /// *different* scenario families coalesce into this one dispatch).
    /// All must fill the artifact's full batch width; pad unused lanes
    /// with zeros = inactive worlds.
    pub fn step_batched(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
    ) -> Result<Vec<StepOutputs>> {
        let mut outs = Vec::new();
        self.step_batched_into(bucket, states, params, geoms, &mut outs)?;
        Ok(outs)
    }

    /// Batched step into a reused output vector: `outs` is resized to
    /// the artifact's batch width and each lane's buffers are refilled
    /// in place — no fresh `Vec`s per lane in steady state.
    pub fn step_batched_into(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        outs: &mut Vec<StepOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        if b < 2 {
            return Err(Error::Artifact(
                "manifest has no batched step artifact; re-run `make artifacts`".into(),
            ));
        }
        if states.len() != b * bucket * STATE_COLS
            || params.len() != b * bucket * PARAM_COLS
            || geoms.len() != b * GEOM_COLS
        {
            return Err(Error::Runtime(format!(
                "batched shape mismatch: states {} params {} geoms {} for batch {b} x bucket {bucket}",
                states.len(),
                params.len(),
                geoms.len()
            )));
        }
        timed(&self.dispatch.step_latency_us, "step", bucket, 0, b, || {
            self.step_batched_dispatch(bucket, states, params, geoms, outs)
        })
    }

    fn step_batched_dispatch(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        outs: &mut Vec<StepOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        let exe = self.executable("stepb", bucket)?;
        let s = xla::Literal::vec1(states)
            .reshape(&[b as i64, bucket as i64, STATE_COLS as i64])
            .map_err(Error::runtime)?;
        let p = xla::Literal::vec1(params)
            .reshape(&[b as i64, bucket as i64, PARAM_COLS as i64])
            .map_err(Error::runtime)?;
        let g = xla::Literal::vec1(geoms)
            .reshape(&[b as i64, GEOM_COLS as i64])
            .map_err(Error::runtime)?;
        let result = exe.execute::<xla::Literal>(&[s, p, g]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ac, ra, ob) = result.to_tuple4().map_err(Error::runtime)?;
        let st = st.to_vec::<f32>().map_err(Error::runtime)?;
        let ac = ac.to_vec::<f32>().map_err(Error::runtime)?;
        let ra = ra.to_vec::<f32>().map_err(Error::runtime)?;
        let ob = ob.to_vec::<f32>().map_err(Error::runtime)?;
        outs.resize_with(b, StepOutputs::default);
        for (i, o) in outs.iter_mut().enumerate() {
            fill(&mut o.state, &st[i * bucket * STATE_COLS..(i + 1) * bucket * STATE_COLS]);
            fill(&mut o.accel, &ac[i * bucket..(i + 1) * bucket]);
            fill(&mut o.radar, &ra[i * bucket * 2..(i + 1) * bucket * 2]);
            fill(&mut o.obs, &ob[i * OBS_COLS..(i + 1) * OBS_COLS]);
        }
        Ok(())
    }

    /// Execute one fused K-step rollout at `bucket` capacity under
    /// `geom` (schema 4): one PJRT dispatch advances the world by `k`
    /// steps and returns the final state plus the per-step obs trace —
    /// bit-identical to `k` sequential [`Engine::step_into`] calls, with
    /// none of their per-step host round-trips.  `k` must be a rung of
    /// the manifest's rollout ladder.
    pub fn rollout(
        &self,
        bucket: usize,
        k: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
    ) -> Result<RolloutOutputs> {
        let mut out = RolloutOutputs::default();
        self.rollout_into(bucket, k, state, params, geom, &mut out)?;
        Ok(out)
    }

    /// [`Engine::rollout`] into a caller-owned [`RolloutOutputs`] — the
    /// chunked hot path (same FFI-boundary caveat as
    /// [`Engine::step_into`]: the two result vectors are swapped in).
    pub fn rollout_into(
        &self,
        bucket: usize,
        k: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        out: &mut RolloutOutputs,
    ) -> Result<()> {
        if state.len() != bucket * STATE_COLS || params.len() != bucket * PARAM_COLS {
            return Err(Error::Runtime(format!(
                "shape mismatch: state {} params {} for bucket {bucket}",
                state.len(),
                params.len()
            )));
        }
        let hist = self.dispatch.rollout(k);
        timed(&hist, "rollout", bucket, k, 1, || {
            self.rollout_dispatch(bucket, k, state, params, geom, out)
        })
    }

    fn rollout_dispatch(
        &self,
        bucket: usize,
        k: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        out: &mut RolloutOutputs,
    ) -> Result<()> {
        let exe = self.rollout_executable("rollout", bucket, k)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let g = xla::Literal::vec1(geom.as_slice());
        let result = exe.execute::<xla::Literal>(&[s, p, g]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ob) = result.to_tuple2().map_err(Error::runtime)?;
        out.state = st.to_vec::<f32>().map_err(Error::runtime)?;
        out.obs = ob.to_vec::<f32>().map_err(Error::runtime)?;
        debug_assert_eq!(out.obs.len(), k * OBS_COLS);
        Ok(())
    }

    /// Batched fused rollout: one PJRT dispatch advances `batch`
    /// co-located instances by `k` steps each via the vmapped
    /// `rolloutb{k}` artifact — the micro-batcher's coalesced chunk
    /// dispatch.  Inputs are concatenations over the full batch width
    /// (pad unused lanes with zeros = inactive worlds); `outs` lanes are
    /// refilled in place like [`Engine::step_batched_into`].
    pub fn rollout_batched_into(
        &self,
        bucket: usize,
        k: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        outs: &mut Vec<RolloutOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        if b < 2 {
            return Err(Error::Artifact(
                "manifest has no batched rollout artifact; re-run `make artifacts`".into(),
            ));
        }
        if states.len() != b * bucket * STATE_COLS
            || params.len() != b * bucket * PARAM_COLS
            || geoms.len() != b * GEOM_COLS
        {
            return Err(Error::Runtime(format!(
                "batched shape mismatch: states {} params {} geoms {} for batch {b} x bucket {bucket}",
                states.len(),
                params.len(),
                geoms.len()
            )));
        }
        let hist = self.dispatch.rollout(k);
        timed(&hist, "rollout", bucket, k, b, || {
            self.rollout_batched_dispatch(bucket, k, states, params, geoms, outs)
        })
    }

    fn rollout_batched_dispatch(
        &self,
        bucket: usize,
        k: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        outs: &mut Vec<RolloutOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        let exe = self.rollout_executable("rolloutb", bucket, k)?;
        let s = xla::Literal::vec1(states)
            .reshape(&[b as i64, bucket as i64, STATE_COLS as i64])
            .map_err(Error::runtime)?;
        let p = xla::Literal::vec1(params)
            .reshape(&[b as i64, bucket as i64, PARAM_COLS as i64])
            .map_err(Error::runtime)?;
        let g = xla::Literal::vec1(geoms)
            .reshape(&[b as i64, GEOM_COLS as i64])
            .map_err(Error::runtime)?;
        let result = exe.execute::<xla::Literal>(&[s, p, g]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ob) = result.to_tuple2().map_err(Error::runtime)?;
        let st = st.to_vec::<f32>().map_err(Error::runtime)?;
        let ob = ob.to_vec::<f32>().map_err(Error::runtime)?;
        debug_assert_eq!(ob.len(), b * k * OBS_COLS);
        outs.resize_with(b, RolloutOutputs::default);
        for (i, o) in outs.iter_mut().enumerate() {
            fill(&mut o.state, &st[i * bucket * STATE_COLS..(i + 1) * bucket * STATE_COLS]);
            fill(&mut o.obs, &ob[i * k * OBS_COLS..(i + 1) * k * OBS_COLS]);
        }
        Ok(())
    }

    /// Execute a WHOLE T-step run at `bucket` capacity as one dispatch
    /// (schema 5): demand rides in as the `departures` table operand
    /// (flattened `f32[D, DEP_COLS]`, `D` = the manifest's
    /// `departure_rows`) and insertion happens in-kernel, so the host
    /// never touches the loop — bit-identical to T sequential
    /// insert-due-then-step iterations.  `t` must be a rung of the
    /// manifest's run ladder.
    pub fn run(
        &self,
        bucket: usize,
        t: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        departures: &[f32],
    ) -> Result<RunOutputs> {
        let mut out = RunOutputs::default();
        self.run_into(bucket, t, state, params, geom, departures, &mut out)?;
        Ok(out)
    }

    /// [`Engine::run`] into a caller-owned [`RunOutputs`] — the
    /// whole-run hot path (same FFI-boundary caveat as
    /// [`Engine::step_into`]: the four result vectors are swapped in).
    #[allow(clippy::too_many_arguments)]
    pub fn run_into(
        &self,
        bucket: usize,
        t: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        departures: &[f32],
        out: &mut RunOutputs,
    ) -> Result<()> {
        let d = self.manifest.departure_rows;
        if state.len() != bucket * STATE_COLS
            || params.len() != bucket * PARAM_COLS
            || departures.len() != d * DEP_COLS
        {
            return Err(Error::Runtime(format!(
                "shape mismatch: state {} params {} departures {} for bucket {bucket} (D={d})",
                state.len(),
                params.len(),
                departures.len()
            )));
        }
        let hist = self.dispatch.run(t);
        timed(&hist, "run", bucket, t, 1, || {
            self.run_dispatch(bucket, t, state, params, geom, departures, out)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_dispatch(
        &self,
        bucket: usize,
        t: usize,
        state: &[f32],
        params: &[f32],
        geom: &GeometryVec,
        departures: &[f32],
        out: &mut RunOutputs,
    ) -> Result<()> {
        let d = self.manifest.departure_rows;
        let exe = self.run_executable("run", bucket, t)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let g = xla::Literal::vec1(geom.as_slice());
        let dep = Self::literal_2d(departures, d, DEP_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s, p, g, dep]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, pr, ob, ins) = result.to_tuple4().map_err(Error::runtime)?;
        out.state = st.to_vec::<f32>().map_err(Error::runtime)?;
        out.params = pr.to_vec::<f32>().map_err(Error::runtime)?;
        out.obs = ob.to_vec::<f32>().map_err(Error::runtime)?;
        out.inserted = ins.to_vec::<f32>().map_err(Error::runtime)?;
        debug_assert_eq!(out.obs.len(), t * OBS_COLS);
        debug_assert_eq!(out.inserted.len(), d);
        Ok(())
    }

    /// Batched whole-run: one PJRT dispatch executes `batch` co-located
    /// T-step runs via the vmapped `runb{t}` artifact — the run lane of
    /// the engine-service micro-batcher.  Inputs are concatenations over
    /// the full batch width (pad unused lanes with zeros = inactive
    /// worlds and all-padding departure tables); `outs` lanes are
    /// refilled in place like [`Engine::rollout_batched_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn run_batched_into(
        &self,
        bucket: usize,
        t: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        departures: &[f32],
        outs: &mut Vec<RunOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        let d = self.manifest.departure_rows;
        if b < 2 {
            return Err(Error::Artifact(
                "manifest has no batched run artifact; re-run `make artifacts`".into(),
            ));
        }
        if states.len() != b * bucket * STATE_COLS
            || params.len() != b * bucket * PARAM_COLS
            || geoms.len() != b * GEOM_COLS
            || departures.len() != b * d * DEP_COLS
        {
            return Err(Error::Runtime(format!(
                "batched shape mismatch: states {} params {} geoms {} departures {} \
                 for batch {b} x bucket {bucket} (D={d})",
                states.len(),
                params.len(),
                geoms.len(),
                departures.len()
            )));
        }
        let hist = self.dispatch.run(t);
        timed(&hist, "run", bucket, t, b, || {
            self.run_batched_dispatch(bucket, t, states, params, geoms, departures, outs)
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn run_batched_dispatch(
        &self,
        bucket: usize,
        t: usize,
        states: &[f32],
        params: &[f32],
        geoms: &[f32],
        departures: &[f32],
        outs: &mut Vec<RunOutputs>,
    ) -> Result<()> {
        let b = self.manifest.batch;
        let d = self.manifest.departure_rows;
        let exe = self.run_executable("runb", bucket, t)?;
        let s = xla::Literal::vec1(states)
            .reshape(&[b as i64, bucket as i64, STATE_COLS as i64])
            .map_err(Error::runtime)?;
        let p = xla::Literal::vec1(params)
            .reshape(&[b as i64, bucket as i64, PARAM_COLS as i64])
            .map_err(Error::runtime)?;
        let g = xla::Literal::vec1(geoms)
            .reshape(&[b as i64, GEOM_COLS as i64])
            .map_err(Error::runtime)?;
        let dep = xla::Literal::vec1(departures)
            .reshape(&[b as i64, d as i64, DEP_COLS as i64])
            .map_err(Error::runtime)?;
        let result = exe
            .execute::<xla::Literal>(&[s, p, g, dep])
            .map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, pr, ob, ins) = result.to_tuple4().map_err(Error::runtime)?;
        let st = st.to_vec::<f32>().map_err(Error::runtime)?;
        let pr = pr.to_vec::<f32>().map_err(Error::runtime)?;
        let ob = ob.to_vec::<f32>().map_err(Error::runtime)?;
        let ins = ins.to_vec::<f32>().map_err(Error::runtime)?;
        debug_assert_eq!(ob.len(), b * t * OBS_COLS);
        outs.resize_with(b, RunOutputs::default);
        for (i, o) in outs.iter_mut().enumerate() {
            fill(&mut o.state, &st[i * bucket * STATE_COLS..(i + 1) * bucket * STATE_COLS]);
            fill(&mut o.params, &pr[i * bucket * PARAM_COLS..(i + 1) * bucket * PARAM_COLS]);
            fill(&mut o.obs, &ob[i * t * OBS_COLS..(i + 1) * t * OBS_COLS]);
            fill(&mut o.inserted, &ins[i * d..(i + 1) * d]);
        }
        Ok(())
    }

    /// Execute the bare IDM kernel (microbench + cross-validation).
    pub fn idm(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable("idm", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s, p]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let out = result.to_tuple1().map_err(Error::runtime)?;
        out.to_vec::<f32>().map_err(Error::runtime)
    }

    /// Execute the bare radar kernel.
    pub fn radar(&self, bucket: usize, state: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable("radar", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let out = result.to_tuple1().map_err(Error::runtime)?;
        out.to_vec::<f32>().map_err(Error::runtime)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::sumo::state::{DriverParams, Traffic};

    fn engine() -> Option<Engine> {
        match Engine::auto() {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping PJRT test: {e}");
                None
            }
        }
    }

    #[test]
    fn engine_boots_cpu_client() {
        let Some(e) = engine() else { return };
        assert_eq!(e.platform().to_lowercase(), "cpu");
    }

    fn default_geom() -> GeometryVec {
        GeometryVec::default()
    }

    #[test]
    fn step_executes_and_preserves_shapes() {
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(150.0, 10.0, 1.0, DriverParams::default());
        let out = e.step(bucket, &t.state, &t.params, &default_geom()).unwrap();
        assert_eq!(out.state.len(), bucket * 4);
        assert_eq!(out.accel.len(), bucket);
        assert_eq!(out.radar.len(), bucket * 2);
        assert_eq!(out.obs.len(), OBS_COLS);
        assert_eq!(out.obs[0], 2.0); // n_active
    }

    #[test]
    fn exit_columns_are_live_in_the_artifact() {
        // the schema-3 executable honours per-vehicle destination
        // intent: same state, flagged params retire at the gore
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        let g = default_geom();
        let mut through = Traffic::new(bucket);
        through.spawn(449.5, 30.0, 1.0, DriverParams::default());
        let out = e.step(bucket, &through.state, &through.params, &g).unwrap();
        assert_eq!(out.obs[4], 0.0, "through vehicle does not exit");
        assert_eq!(out.obs[2], 0.0);
        let mut exiting = Traffic::new(bucket);
        exiting.spawn(449.5, 30.0, 1.0, DriverParams::default().with_exit(450.0));
        assert_eq!(exiting.state, through.state, "same state, different params");
        let out = e.step(bucket, &exiting.state, &exiting.params, &g).unwrap();
        assert_eq!(out.obs[4], 1.0, "exit_pos crossing ticks n_exited");
        assert_eq!(out.obs[2], 0.0, "flow does not double-count the exit");
        assert_eq!(out.obs[0], 1.0);
    }

    #[test]
    fn step_into_repeats_cleanly() {
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let g = default_geom();
        let mut out = StepOutputs::default();
        e.step_into(bucket, &t.state, &t.params, &g, &mut out).unwrap();
        let first = out.clone();
        // repeat into the same StepOutputs: identical results, no stale
        // data surviving from the previous call
        e.step_into(bucket, &t.state, &t.params, &g, &mut out).unwrap();
        assert_eq!(out, first);
        assert_eq!(e.step(bucket, &t.state, &t.params, &g).unwrap(), first);
    }

    #[test]
    fn geometry_operand_is_live() {
        // the executable honours the geometry operand: pulling road_end
        // in front of the vehicle retires it (no recompile involved)
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(390.0, 30.0, 1.0, DriverParams::default());
        let far = e.step(bucket, &t.state, &t.params, &default_geom()).unwrap();
        assert_eq!(far.obs[0], 1.0);
        assert_eq!(far.obs[2], 0.0, "default road end is 1000 m away");
        let near = crate::sumo::MergeScenario {
            road_end_m: 392.0,
            ..crate::sumo::MergeScenario::default()
        };
        let out = e.step(bucket, &t.state, &t.params, &near.geometry_vec()).unwrap();
        assert_eq!(out.obs[2], 1.0, "operand road end just ahead: flow ticks");
    }

    #[test]
    fn step_batched_into_reuses_lane_buffers() {
        let Some(e) = engine() else { return };
        let b = e.manifest().batch;
        if b < 2 {
            eprintln!("no batched artifact; skipping");
            return;
        }
        let bucket = e.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let g = default_geom();
        let mut states = Vec::new();
        let mut params = Vec::new();
        let mut geoms = Vec::new();
        for _ in 0..b {
            states.extend_from_slice(&t.state);
            params.extend_from_slice(&t.params);
            geoms.extend_from_slice(g.as_slice());
        }
        let mut outs = Vec::new();
        e.step_batched_into(bucket, &states, &params, &geoms, &mut outs).unwrap();
        let first = outs.clone();
        let ptrs: Vec<*const f32> = outs.iter().map(|o| o.state.as_ptr()).collect();
        // second dispatch refills the same per-lane buffers in place
        e.step_batched_into(bucket, &states, &params, &geoms, &mut outs).unwrap();
        assert_eq!(outs, first);
        for (o, p) in outs.iter().zip(ptrs) {
            assert_eq!(o.state.as_ptr(), p, "lane buffer reallocated");
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        assert!(e.step(bucket, &[0.0; 4], &[0.0; 6], &default_geom()).is_err());
        assert!(e.rollout(bucket, 1, &[0.0; 4], &[0.0; 6], &default_geom()).is_err());
    }

    /// The tentpole ABI guarantee at the engine level: one fused K-step
    /// dispatch == K sequential step dispatches, bit for bit — state and
    /// the whole per-step obs trace, exits included (an exit-flagged
    /// vehicle retires mid-chunk inside the scan carry).
    #[test]
    fn rollout_bit_exact_with_sequential_steps() {
        let Some(e) = engine() else { return };
        if !e.manifest().rollouts_available() {
            eprintln!("skipping: artifacts predate schema 4");
            return;
        }
        let bucket = e.manifest().buckets[0];
        let g = default_geom();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(160.0, 25.0, 2.0, DriverParams::cav());
        // gore ~3 steps ahead: this one retires mid-chunk
        t.spawn(440.0, 30.0, 1.0, DriverParams::default().with_exit(450.0));
        for &k in &e.manifest().rollout_steps.clone() {
            let mut seq_state = t.state.clone();
            let mut seq_obs = Vec::new();
            let mut step_out = StepOutputs::default();
            for _ in 0..k {
                e.step_into(bucket, &seq_state, &t.params, &g, &mut step_out).unwrap();
                seq_state.copy_from_slice(&step_out.state);
                seq_obs.extend_from_slice(&step_out.obs);
            }
            let out = e.rollout(bucket, k, &t.state, &t.params, &g).unwrap();
            assert_eq!(out.steps(), k);
            assert_eq!(out.state, seq_state, "K={k}: final state diverged");
            assert_eq!(out.obs, seq_obs, "K={k}: obs trace diverged");
        }
        // the chunk really contained the exit
        let out = e.rollout(bucket, 8, &t.state, &t.params, &g).unwrap();
        let exits: f32 = (0..8).map(|i| out.obs_row(i)[4]).sum();
        assert_eq!(exits, 1.0, "exit must retire inside the fused chunk");
    }

    #[test]
    fn rollout_into_reuses_buffers_and_rejects_unknown_k() {
        let Some(e) = engine() else { return };
        if !e.manifest().rollouts_available() {
            return;
        }
        let bucket = e.manifest().buckets[0];
        let g = default_geom();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        let mut out = RolloutOutputs::default();
        e.rollout_into(bucket, 8, &t.state, &t.params, &g, &mut out).unwrap();
        let first = out.clone();
        e.rollout_into(bucket, 8, &t.state, &t.params, &g, &mut out).unwrap();
        assert_eq!(out, first);
        // a K that was never lowered is a loud artifact error
        assert!(e.rollout(bucket, 7, &t.state, &t.params, &g).is_err());
    }

    #[test]
    fn rollout_batched_lanes_match_solo_rollouts() {
        let Some(e) = engine() else { return };
        if !e.manifest().rollouts_available() {
            return;
        }
        let b = e.manifest().batch;
        if b < 2 {
            eprintln!("no batched rollout artifact; skipping");
            return;
        }
        let bucket = e.manifest().buckets[0];
        let g = default_geom();
        let k = *e.manifest().rollout_steps.last().unwrap();
        let worlds: Vec<Traffic> = (0..b)
            .map(|i| {
                let mut t = Traffic::new(bucket);
                t.spawn(30.0 + 40.0 * i as f32, 8.0 + 2.0 * i as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        let mut states = Vec::new();
        let mut params = Vec::new();
        let mut geoms = Vec::new();
        for w in &worlds {
            states.extend_from_slice(&w.state);
            params.extend_from_slice(&w.params);
            geoms.extend_from_slice(g.as_slice());
        }
        let mut outs = Vec::new();
        e.rollout_batched_into(bucket, k, &states, &params, &geoms, &mut outs).unwrap();
        assert_eq!(outs.len(), b);
        // the vmapped lowering may fuse differently from the solo one,
        // so batched-vs-solo is tolerance-checked (bit-exactness is
        // claimed fused-vs-sequential, not batched-vs-solo — same
        // discipline as python/tests/test_aot.py)
        let close = |a: &[f32], b: &[f32]| {
            a.len() == b.len()
                && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-4)
        };
        for (i, (w, lane)) in worlds.iter().zip(&outs).enumerate() {
            let solo = e.rollout(bucket, k, &w.state, &w.params, &g).unwrap();
            assert!(close(&lane.state, &solo.state), "lane {i} state diverged");
            assert!(close(&lane.obs, &solo.obs), "lane {i} obs diverged");
        }
        // lane buffers are reused across dispatches
        let ptrs: Vec<*const f32> = outs.iter().map(|o| o.state.as_ptr()).collect();
        e.rollout_batched_into(bucket, k, &states, &params, &geoms, &mut outs).unwrap();
        for (o, p) in outs.iter().zip(ptrs) {
            assert_eq!(o.state.as_ptr(), p, "lane buffer reallocated");
        }
    }

    /// An all-padding departure table: no row ever comes due.
    fn empty_table(d: usize) -> Vec<f32> {
        let mut rows = vec![0.0f32; d * DEP_COLS];
        for i in 0..d {
            rows[i * DEP_COLS] = crate::sumo::DEP_PAD_EPOCH;
        }
        rows
    }

    /// The schema-5 ABI guarantee with no demand: one whole-run dispatch
    /// == T sequential step dispatches, bit for bit — final state and
    /// the whole obs trace — and the untouched params rows round-trip.
    #[test]
    fn run_with_empty_table_matches_sequential_steps() {
        let Some(e) = engine() else { return };
        if !e.manifest().runs_available() {
            eprintln!("skipping: artifacts predate schema 5");
            return;
        }
        let bucket = e.manifest().buckets[0];
        let t_steps = e.manifest().run_steps[0];
        let d = e.manifest().departure_rows;
        let g = default_geom();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(160.0, 25.0, 2.0, DriverParams::cav());
        let mut seq_state = t.state.clone();
        let mut seq_obs = Vec::new();
        let mut step_out = StepOutputs::default();
        for _ in 0..t_steps {
            e.step_into(bucket, &seq_state, &t.params, &g, &mut step_out).unwrap();
            seq_state.copy_from_slice(&step_out.state);
            seq_obs.extend_from_slice(&step_out.obs);
        }
        let out = e.run(bucket, t_steps, &t.state, &t.params, &g, &empty_table(d)).unwrap();
        assert_eq!(out.steps(), t_steps);
        assert_eq!(out.state, seq_state, "T={t_steps}: final state diverged");
        assert_eq!(out.obs, seq_obs, "T={t_steps}: obs trace diverged");
        assert_eq!(out.params, t.params, "no spawn: params must round-trip");
        assert!(out.inserted.iter().all(|&m| m == 0.0));
        // a T that was never lowered is a loud artifact error
        assert!(e.run(bucket, 7, &t.state, &t.params, &g, &empty_table(d)).is_err());
    }

    /// In-kernel insertion: a table row comes due mid-run, spawns into
    /// the first inactive slot exactly like the host scheduler would,
    /// and the whole run stays bit-exact with a sequential mirror that
    /// performs the same insert host-side.
    #[test]
    fn run_inserts_departures_in_kernel() {
        let Some(e) = engine() else { return };
        if !e.manifest().runs_available() {
            return;
        }
        let bucket = e.manifest().buckets[0];
        let t_steps = e.manifest().run_steps[0];
        let d = e.manifest().departure_rows;
        let g = default_geom();
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(160.0, 25.0, 2.0, DriverParams::cav());
        // the spawn payload, via a scratch world so the test never
        // hand-writes the params layout
        let mut scratch = Traffic::new(4);
        // exit ~150 m ahead: reached well inside the shortest (200-step
        // = 20 s) rung, so the retirement is observable in the trace
        scratch.spawn(10.0, 20.0, 1.0, DriverParams::default().with_exit(150.0));
        let spawn_state = &scratch.state[0..STATE_COLS];
        let spawn_params = &scratch.params[0..PARAM_COLS];
        let epoch = 5usize;
        let mut table = empty_table(d);
        table[0] = epoch as f32;
        table[1..4].copy_from_slice(&spawn_state[0..3]);
        table[4..DEP_COLS].copy_from_slice(spawn_params);
        let mut seq_state = t.state.clone();
        let mut seq_params = t.params.clone();
        let mut seq_obs = Vec::new();
        let mut step_out = StepOutputs::default();
        for s in 0..t_steps {
            if s == epoch {
                let slot = (0..bucket)
                    .find(|&i| seq_state[i * STATE_COLS + 3] < 0.5)
                    .unwrap();
                seq_state[slot * STATE_COLS..(slot + 1) * STATE_COLS]
                    .copy_from_slice(spawn_state);
                seq_params[slot * PARAM_COLS..(slot + 1) * PARAM_COLS]
                    .copy_from_slice(spawn_params);
            }
            e.step_into(bucket, &seq_state, &seq_params, &g, &mut step_out).unwrap();
            seq_state.copy_from_slice(&step_out.state);
            seq_obs.extend_from_slice(&step_out.obs);
        }
        let out = e.run(bucket, t_steps, &t.state, &t.params, &g, &table).unwrap();
        assert_eq!(out.inserted[0], 1.0, "the due row must insert");
        assert!(out.inserted[1..].iter().all(|&m| m == 0.0));
        assert_eq!(out.state, seq_state, "final state diverged");
        assert_eq!(out.obs, seq_obs, "obs trace diverged");
        assert_eq!(out.params, seq_params, "spawned params row missing");
        // the spawn was exit-flagged at 450 m: it must retire inside the
        // run (n_exited ticks once, so insertion really happened at the
        // epoch, not at step 0)
        let exits: f32 = (0..t_steps).map(|i| out.obs_row(i)[4]).sum();
        assert_eq!(exits, 1.0, "in-kernel spawn must run and exit");
        assert_eq!(out.obs_row(epoch)[0], 3.0, "n_active ticks at the epoch");
        assert_eq!(out.obs_row(epoch - 1)[0], 2.0, "not before it");
    }

    /// Batched whole-run lanes match solo runs (tolerance-checked, same
    /// discipline as the batched rollout test — bit-exactness is claimed
    /// fused-vs-sequential, not batched-vs-solo).
    #[test]
    fn run_batched_lanes_match_solo_runs() {
        let Some(e) = engine() else { return };
        if !e.manifest().runs_available() {
            return;
        }
        let b = e.manifest().batch;
        if b < 2 {
            eprintln!("no batched run artifact; skipping");
            return;
        }
        let bucket = e.manifest().buckets[0];
        let t_steps = e.manifest().run_steps[0];
        let d = e.manifest().departure_rows;
        let g = default_geom();
        let worlds: Vec<Traffic> = (0..b)
            .map(|i| {
                let mut t = Traffic::new(bucket);
                t.spawn(30.0 + 40.0 * i as f32, 8.0 + 2.0 * i as f32, 1.0, DriverParams::default());
                t
            })
            .collect();
        let mut states = Vec::new();
        let mut params = Vec::new();
        let mut geoms = Vec::new();
        let mut departures = Vec::new();
        for _ in &worlds {
            departures.extend_from_slice(&empty_table(d));
        }
        for w in &worlds {
            states.extend_from_slice(&w.state);
            params.extend_from_slice(&w.params);
            geoms.extend_from_slice(g.as_slice());
        }
        let mut outs = Vec::new();
        e.run_batched_into(bucket, t_steps, &states, &params, &geoms, &departures, &mut outs)
            .unwrap();
        assert_eq!(outs.len(), b);
        let close = |a: &[f32], b: &[f32]| {
            a.len() == b.len() && a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 1e-4)
        };
        for (i, (w, lane)) in worlds.iter().zip(&outs).enumerate() {
            let solo = e.run(bucket, t_steps, &w.state, &w.params, &g, &empty_table(d)).unwrap();
            assert!(close(&lane.state, &solo.state), "lane {i} state diverged");
            assert!(close(&lane.obs, &solo.obs), "lane {i} obs diverged");
        }
        let ptrs: Vec<*const f32> = outs.iter().map(|o| o.state.as_ptr()).collect();
        e.run_batched_into(bucket, t_steps, &states, &params, &geoms, &departures, &mut outs)
            .unwrap();
        for (o, p) in outs.iter().zip(ptrs) {
            assert_eq!(o.state.as_ptr(), p, "lane buffer reallocated");
        }
    }
}
