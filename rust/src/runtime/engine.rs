//! The PJRT engine: compile HLO-text artifacts, execute them on the hot
//! path, and adapt the step artifact to the [`Stepper`] trait.

use std::path::PathBuf;
use std::rc::Rc;
use std::sync::Arc;

use crate::sumo::state::{PARAM_COLS, STATE_COLS};
use crate::{Error, Result};

use super::manifest::Manifest;
use super::pool::ExecutablePool;

/// The outputs of one AOT step execution.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutputs {
    /// f32[N*4] — next state rows.
    pub state: Vec<f32>,
    /// f32[N] — accelerations.
    pub accel: Vec<f32>,
    /// f32[N*2] — radar returns.
    pub radar: Vec<f32>,
    /// f32[4] — [n_active, mean_speed, flow, n_merged].
    pub obs: Vec<f32>,
}

/// The engine: a PJRT CPU client + the artifact manifest + a pool of
/// compiled executables (one per artifact, compiled lazily, shared).
pub struct Engine {
    client: Rc<xla::PjRtClient>,
    manifest: Manifest,
    dir: PathBuf,
    pool: ExecutablePool,
}

impl Engine {
    /// Construct from an artifacts directory (see
    /// [`super::find_artifacts_dir`]).
    pub fn new(dir: PathBuf) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        manifest.validate_against_default_scenario()?;
        let client = xla::PjRtClient::cpu().map_err(Error::runtime)?;
        Ok(Engine {
            client: Rc::new(client),
            manifest,
            dir,
            pool: ExecutablePool::new(),
        })
    }

    /// Convenience: locate artifacts automatically.
    pub fn auto() -> Result<Engine> {
        let dir = super::find_artifacts_dir()
            .ok_or_else(|| Error::Artifact("artifacts/ not found; run `make artifacts`".into()))?;
        Engine::new(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from the pool) the artifact `name_{bucket}`.
    fn executable(&self, name: &str, bucket: usize) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let entry = self.manifest.entry(name, bucket)?;
        let path = self.dir.join(&entry.file);
        self.pool.get_or_compile(&format!("{name}_{bucket}"), || {
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(Error::runtime)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client.compile(&comp).map_err(Error::runtime)
        })
    }

    fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(Error::runtime)
    }

    /// Execute one full merge-sim step at `bucket` capacity.
    pub fn step(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<StepOutputs> {
        if state.len() != bucket * STATE_COLS || params.len() != bucket * PARAM_COLS {
            return Err(Error::Runtime(format!(
                "shape mismatch: state {} params {} for bucket {bucket}",
                state.len(),
                params.len()
            )));
        }
        let exe = self.executable("step", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s, p]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ac, ra, ob) = result.to_tuple4().map_err(Error::runtime)?;
        Ok(StepOutputs {
            state: st.to_vec::<f32>().map_err(Error::runtime)?,
            accel: ac.to_vec::<f32>().map_err(Error::runtime)?,
            radar: ra.to_vec::<f32>().map_err(Error::runtime)?,
            obs: ob.to_vec::<f32>().map_err(Error::runtime)?,
        })
    }

    /// Execute one merge-sim step for `batch` co-located instances at
    /// once via the vmapped `stepb` artifact — the dynamic micro-batcher
    /// of the engine service (EXPERIMENTS.md §Perf).  `states` is the
    /// concatenation of `batch` state arrays (must fill the artifact's
    /// full batch width; pad unused lanes with zeros = inactive worlds).
    pub fn step_batched(
        &self,
        bucket: usize,
        states: &[f32],
        params: &[f32],
    ) -> Result<Vec<StepOutputs>> {
        let b = self.manifest.batch;
        if b < 2 {
            return Err(Error::Artifact(
                "manifest has no batched step artifact; re-run `make artifacts`".into(),
            ));
        }
        if states.len() != b * bucket * STATE_COLS || params.len() != b * bucket * PARAM_COLS {
            return Err(Error::Runtime(format!(
                "batched shape mismatch: states {} params {} for batch {b} x bucket {bucket}",
                states.len(),
                params.len()
            )));
        }
        let exe = self.executable("stepb", bucket)?;
        let s = xla::Literal::vec1(states)
            .reshape(&[b as i64, bucket as i64, STATE_COLS as i64])
            .map_err(Error::runtime)?;
        let p = xla::Literal::vec1(params)
            .reshape(&[b as i64, bucket as i64, PARAM_COLS as i64])
            .map_err(Error::runtime)?;
        let result = exe.execute::<xla::Literal>(&[s, p]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let (st, ac, ra, ob) = result.to_tuple4().map_err(Error::runtime)?;
        let st = st.to_vec::<f32>().map_err(Error::runtime)?;
        let ac = ac.to_vec::<f32>().map_err(Error::runtime)?;
        let ra = ra.to_vec::<f32>().map_err(Error::runtime)?;
        let ob = ob.to_vec::<f32>().map_err(Error::runtime)?;
        Ok((0..b)
            .map(|i| StepOutputs {
                state: st[i * bucket * STATE_COLS..(i + 1) * bucket * STATE_COLS].to_vec(),
                accel: ac[i * bucket..(i + 1) * bucket].to_vec(),
                radar: ra[i * bucket * 2..(i + 1) * bucket * 2].to_vec(),
                obs: ob[i * 4..(i + 1) * 4].to_vec(),
            })
            .collect())
    }

    /// Execute the bare IDM kernel (microbench + cross-validation).
    pub fn idm(&self, bucket: usize, state: &[f32], params: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable("idm", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let p = Self::literal_2d(params, bucket, PARAM_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s, p]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let out = result.to_tuple1().map_err(Error::runtime)?;
        out.to_vec::<f32>().map_err(Error::runtime)
    }

    /// Execute the bare radar kernel.
    pub fn radar(&self, bucket: usize, state: &[f32]) -> Result<Vec<f32>> {
        let exe = self.executable("radar", bucket)?;
        let s = Self::literal_2d(state, bucket, STATE_COLS)?;
        let result = exe.execute::<xla::Literal>(&[s]).map_err(Error::runtime)?[0][0]
            .to_literal_sync()
            .map_err(Error::runtime)?;
        let out = result.to_tuple1().map_err(Error::runtime)?;
        out.to_vec::<f32>().map_err(Error::runtime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::state::{DriverParams, Traffic};

    fn engine() -> Option<Engine> {
        match Engine::auto() {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping PJRT test: {e}");
                None
            }
        }
    }

    #[test]
    fn engine_boots_cpu_client() {
        let Some(e) = engine() else { return };
        assert_eq!(e.platform().to_lowercase(), "cpu");
    }

    #[test]
    fn step_executes_and_preserves_shapes() {
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        let mut t = Traffic::new(bucket);
        t.spawn(100.0, 20.0, 1.0, DriverParams::default());
        t.spawn(150.0, 10.0, 1.0, DriverParams::default());
        let out = e.step(bucket, &t.state, &t.params).unwrap();
        assert_eq!(out.state.len(), bucket * 4);
        assert_eq!(out.accel.len(), bucket);
        assert_eq!(out.radar.len(), bucket * 2);
        assert_eq!(out.obs.len(), 4);
        assert_eq!(out.obs[0], 2.0); // n_active
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(e) = engine() else { return };
        let bucket = e.manifest().buckets[0];
        assert!(e.step(bucket, &[0.0; 4], &[0.0; 6]).is_err());
    }
}
