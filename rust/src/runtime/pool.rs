//! A shared pool of compiled PJRT executables.
//!
//! Compiling an HLO module costs tens of milliseconds; the campaign
//! launcher runs hundreds of instances of the *same* model, so compiled
//! executables are cached by artifact key and shared via `Arc`.  The
//! pool is a perf ablation (`DESIGN.md` §7): `rust/benches/ablations.rs`
//! measures per-instance compile vs pooled.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::Result;

/// Key → compiled executable cache.
pub struct ExecutablePool {
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    hits: Mutex<u64>,
    misses: Mutex<u64>,
}

impl Default for ExecutablePool {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecutablePool {
    pub fn new() -> Self {
        ExecutablePool {
            cache: Mutex::new(HashMap::new()),
            hits: Mutex::new(0),
            misses: Mutex::new(0),
        }
    }

    /// Fetch the executable for `key`, compiling with `compile` on miss.
    ///
    /// The compile runs *outside* the cache lock (compilation is slow and
    /// other keys shouldn't stall); a racing double-compile of the same
    /// key is benign — last writer wins, both results are valid.
    pub fn get_or_compile<F>(&self, key: &str, compile: F) -> Result<Arc<xla::PjRtLoadedExecutable>>
    where
        F: FnOnce() -> Result<xla::PjRtLoadedExecutable>,
    {
        if let Some(exe) = self.cache.lock().expect("pool poisoned").get(key) {
            *self.hits.lock().expect("pool poisoned") += 1;
            return Ok(exe.clone());
        }
        *self.misses.lock().expect("pool poisoned") += 1;
        let exe = Arc::new(compile()?);
        self.cache
            .lock()
            .expect("pool poisoned")
            .insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    /// (hits, misses) — observability for the perf pass.
    pub fn stats(&self) -> (u64, u64) {
        (
            *self.hits.lock().expect("pool poisoned"),
            *self.misses.lock().expect("pool poisoned"),
        )
    }

    pub fn len(&self) -> usize {
        self.cache.lock().expect("pool poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
