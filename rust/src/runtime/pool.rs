//! A shared pool of compiled PJRT executables.
//!
//! Compiling an HLO module costs tens of milliseconds; the campaign
//! launcher runs hundreds of instances of the *same* model, so compiled
//! executables are cached by artifact key and shared via `Arc`.  The
//! pool is a perf ablation (`DESIGN.md` §7): `rust/benches/ablations.rs`
//! measures per-instance compile vs pooled.
//!
//! The lookup sits on the per-step hot path (every `Engine::step_into`
//! fetches its executable), so the steady state is kept allocation- and
//! contention-free: keys are `(&'static str, bucket)` pairs (no
//! `format!` per call), the cache is behind a read-mostly `RwLock`, and
//! the hit/miss counters are relaxed atomics instead of mutexes.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::telemetry::metrics::{counter, Counter};
use crate::util::SharedCache;
use crate::Result;

/// Cache key: artifact kernel name + vehicle-count bucket + fused-step
/// count (0 for the single-step entries; the K-ladder rung for schema-4
/// rollout executables; the total-steps rung for schema-5 whole-run
/// executables).  The run kind rides the name slot (`"run"`/`"runb"` vs
/// `"rollout"`/`"rolloutb"`), so a run and a rollout of the same bucket
/// and step count never collide.  Still fully static — no `format!` on
/// the per-dispatch lookup path.
pub type PoolKey = (&'static str, usize, usize);

/// Key → compiled executable cache.  The probe/build/insert protocol
/// lives in [`SharedCache`] (util/cache.rs), where the loom model in
/// `rust/tests/loom_models.rs` checks it exhaustively.
pub struct ExecutablePool {
    cache: SharedCache<PoolKey, xla::PjRtLoadedExecutable>,
    hits: AtomicU64,
    misses: AtomicU64,
    // the same counts folded into the process-global telemetry registry
    // (`engine.pool.*`) — the per-engine atomics stay authoritative for
    // `stats()`, the registry aggregates across engines; handles are
    // fetched once here so the registry lock never sits on the lookup
    // path
    global_hits: Arc<Counter>,
    global_misses: Arc<Counter>,
}

impl Default for ExecutablePool {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecutablePool {
    pub fn new() -> Self {
        ExecutablePool {
            cache: SharedCache::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            global_hits: counter("engine.pool.hits"),
            global_misses: counter("engine.pool.misses"),
        }
    }

    /// Fetch the executable for `key`, compiling with `compile` on miss.
    ///
    /// The compile runs *outside* any lock (compilation is slow and
    /// other keys shouldn't stall); a racing double-compile of the same
    /// key is benign — last writer wins, both results are valid.
    pub fn get_or_compile<F>(
        &self,
        key: PoolKey,
        compile: F,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>>
    where
        F: FnOnce() -> Result<xla::PjRtLoadedExecutable>,
    {
        let (exe, hit) = self.cache.get_or_try_insert(key, compile)?;
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.global_hits.inc();
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            self.global_misses.inc();
        }
        Ok(exe)
    }

    /// (hits, misses) — observability for the perf pass.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}
