//! The PJRT runtime: loads the AOT-compiled JAX/Pallas physics and runs
//! it from the rust hot path.
//!
//! Build-time python (`make artifacts`) lowers the merge-sim step, the
//! bare IDM kernel and the radar kernel to HLO **text** per vehicle-count
//! bucket; this module compiles them on the PJRT CPU client and exposes
//! them behind the [`crate::sumo::Stepper`] trait so a simulation can
//! swap between the native-rust baseline and the AOT artifact.
//!
//! HLO text (not serialized proto) is the interchange format: jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see `python/compile/aot.py`).

#![deny(clippy::unwrap_used, clippy::expect_used)]

mod engine;
mod manifest;
mod pool;
mod service;

pub use engine::{Engine, RolloutOutputs, RunOutputs, StepOutputs};
pub use manifest::{ArtifactEntry, Manifest};
pub use pool::{ExecutablePool, PoolKey};
pub use service::{EngineService, EngineSession, HloStepper};

/// Default artifacts directory relative to the repo root.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or
/// the `WEBOTS_HPC_ARTIFACTS` env override (tests and examples run from
/// various depths inside the workspace).
pub fn find_artifacts_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("WEBOTS_HPC_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}
