//! The distributed campaign fabric: coordinator/worker execution over
//! TCP (ISSUE 8).
//!
//! The paper's pipeline runs one campaign across many PBS nodes; until
//! now this repo could only *simulate* that topology inside one
//! process.  This module makes the distribution real at the transport
//! level while changing nothing about what a campaign *is*:
//!
//! * [`Coordinator`] owns the crash-safe campaign ledger and leases
//!   out `(epoch, slot)` coordinates over newline-delimited JSON; the
//!   wire never carries scenario payloads because any worker holding
//!   the same spec materializes the identical run from its index
//!   (`plan_run`'s pure sampler contract),
//! * [`run_worker`] executes leases through the exact same local run
//!   supervisor (containment, taxonomy, retry, watchdogs, degradation)
//!   the single-process driver uses,
//! * heartbeats + the coordinator's reaper thread enforce lease
//!   deadlines from *outside* every worker process — a killed worker's
//!   leases are revoked and re-dispatched, and a zombie's late result
//!   lands in the ledger's idempotent duplicate guard,
//! * the final aggregate is assembled by the same ledger+disk walk as
//!   the local driver, so the distributed dataset is byte-identical to
//!   the single-process one, including across a coordinator kill and
//!   resume.
//!
//! Robustness discipline matches the rest of the pipeline: no
//! `unwrap`/`expect` outside tests, torn frames and duplicate
//! completions are first-class protocol citizens, and every fault the
//! soak injects maps to a site in [`crate::pipeline::FaultPlan`].
#![deny(clippy::unwrap_used, clippy::expect_used)]

// Only the lease table compiles under `--cfg loom` — it is the state
// the expire-vs-complete model in rust/tests/loom_models.rs races on.
#[cfg(not(loom))]
pub mod coordinator;
pub mod lease;
#[cfg(not(loom))]
pub mod protocol;
#[cfg(not(loom))]
pub mod worker;

#[cfg(not(loom))]
pub use coordinator::{Coordinator, FabricConfig, FabricOutcome, FabricStats};
pub use lease::{Lease, LeaseTable};
#[cfg(not(loom))]
pub use protocol::{spec_hash, Msg};
#[cfg(not(loom))]
pub use worker::{run_worker, WorkerConfig, WorkerKill, WorkerOutcome};
