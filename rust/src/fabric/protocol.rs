//! The fabric wire protocol: newline-delimited compact JSON, one
//! message per line — the same framing as the telemetry stream and the
//! campaign ledger, so every layer of the system shares one torn-line
//! discipline.
//!
//! The protocol carries *coordinates, not payloads*: a lease names a
//! run index, and the worker materializes the full instance from the
//! campaign spec it already holds (the pure `(space, seed, index) →
//! point` sampler contract).  The only bulk transfer is the finished
//! run's CSV riding home inside a `complete` frame — JSON string
//! escaping keeps the newlines of the CSV out of the framing.

use std::io::{Read, Write};
use std::net::TcpStream;

use crate::pipeline::SupervisedCampaignSpec;
use crate::telemetry::Event;
use crate::util::Json;
use crate::{Error, Result};

/// Every frame either side can send.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker → coordinator: handshake.  `spec_hash` binds the worker
    /// to one campaign shape — the wire mirror of the ledger header.
    Hello { worker: String, spec_hash: String },
    /// Coordinator → worker: handshake accepted; heartbeat cadence and
    /// the lease TTL the reaper enforces.
    Welcome { heartbeat_ms: u64, lease_ttl_ms: u64 },
    /// Coordinator → worker: handshake rejected (wrong campaign shape).
    Refuse { reason: String },
    /// Worker → coordinator: give me work.
    Request,
    /// Coordinator → worker: run campaign index `idx` under lease
    /// `lease` (`attempt` counts fabric-level dispatches of this slot).
    Lease { lease: u64, idx: u64, attempt: u64 },
    /// Coordinator → worker: nothing leasable right now (everything is
    /// out on other leases) — ask again in `ms`.
    Wait { ms: u64 },
    /// Coordinator → worker: the campaign is settled (or stopping) —
    /// disconnect.
    Drain,
    /// Worker → coordinator: lease `lease` is still alive.
    Heartbeat { lease: u64 },
    /// Worker → coordinator: a forwarded telemetry event.
    Event { event: Event },
    /// Worker → coordinator: run finished; the CSV rides inline.
    Complete {
        lease: u64,
        idx: u64,
        run_id: String,
        attempts: u64,
        degraded: bool,
        csv: String,
    },
    /// Worker → coordinator: run failed terminally on the worker
    /// (local retry budget exhausted or a permanent error).
    Failed {
        lease: u64,
        idx: u64,
        run_id: String,
        attempts: u64,
        class: String,
        error: String,
    },
}

fn num(n: u64) -> Json {
    Json::num(n as f64)
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)?.as_str()?.to_string())
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(j.get(key)?.as_f64()? as u64)
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    match j.get(key)? {
        Json::Bool(b) => Ok(*b),
        other => Err(Error::Protocol(format!(
            "expected bool for '{key}', got {other:?}"
        ))),
    }
}

impl Msg {
    /// The `"msg"` tag this frame serializes under.
    pub fn tag(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::Welcome { .. } => "welcome",
            Msg::Refuse { .. } => "refuse",
            Msg::Request => "request",
            Msg::Lease { .. } => "lease",
            Msg::Wait { .. } => "wait",
            Msg::Drain => "drain",
            Msg::Heartbeat { .. } => "heartbeat",
            Msg::Event { .. } => "event",
            Msg::Complete { .. } => "complete",
            Msg::Failed { .. } => "failed",
        }
    }

    /// One compact JSON object: `{"msg": <tag>, ...fields}`.
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![("msg", Json::str(self.tag()))];
        match self {
            Msg::Hello { worker, spec_hash } => {
                pairs.push(("worker", Json::str(worker.clone())));
                pairs.push(("spec_hash", Json::str(spec_hash.clone())));
            }
            Msg::Welcome {
                heartbeat_ms,
                lease_ttl_ms,
            } => {
                pairs.push(("heartbeat_ms", num(*heartbeat_ms)));
                pairs.push(("lease_ttl_ms", num(*lease_ttl_ms)));
            }
            Msg::Refuse { reason } => {
                pairs.push(("reason", Json::str(reason.clone())));
            }
            Msg::Request | Msg::Drain => {}
            Msg::Lease { lease, idx, attempt } => {
                pairs.push(("lease", num(*lease)));
                pairs.push(("idx", num(*idx)));
                pairs.push(("attempt", num(*attempt)));
            }
            Msg::Wait { ms } => {
                pairs.push(("ms", num(*ms)));
            }
            Msg::Heartbeat { lease } => {
                pairs.push(("lease", num(*lease)));
            }
            Msg::Event { event } => {
                pairs.push(("event", event.to_json()));
            }
            Msg::Complete {
                lease,
                idx,
                run_id,
                attempts,
                degraded,
                csv,
            } => {
                pairs.push(("lease", num(*lease)));
                pairs.push(("idx", num(*idx)));
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempts", num(*attempts)));
                pairs.push(("degraded", Json::Bool(*degraded)));
                pairs.push(("csv", Json::str(csv.clone())));
            }
            Msg::Failed {
                lease,
                idx,
                run_id,
                attempts,
                class,
                error,
            } => {
                pairs.push(("lease", num(*lease)));
                pairs.push(("idx", num(*idx)));
                pairs.push(("run_id", Json::str(run_id.clone())));
                pairs.push(("attempts", num(*attempts)));
                pairs.push(("class", Json::str(class.clone())));
                pairs.push(("error", Json::str(error.clone())));
            }
        }
        Json::obj(pairs)
    }

    /// Inverse of [`Msg::to_json`] — unknown tags and missing fields
    /// are protocol errors (the sender is confused or the frame was
    /// corrupted in a way the line framing didn't catch).
    pub fn from_json(j: &Json) -> Result<Msg> {
        let tag = j.get("msg")?.as_str()?.to_string();
        Ok(match tag.as_str() {
            "hello" => Msg::Hello {
                worker: get_str(j, "worker")?,
                spec_hash: get_str(j, "spec_hash")?,
            },
            "welcome" => Msg::Welcome {
                heartbeat_ms: get_u64(j, "heartbeat_ms")?,
                lease_ttl_ms: get_u64(j, "lease_ttl_ms")?,
            },
            "refuse" => Msg::Refuse {
                reason: get_str(j, "reason")?,
            },
            "request" => Msg::Request,
            "lease" => Msg::Lease {
                lease: get_u64(j, "lease")?,
                idx: get_u64(j, "idx")?,
                attempt: get_u64(j, "attempt")?,
            },
            "wait" => Msg::Wait {
                ms: get_u64(j, "ms")?,
            },
            "drain" => Msg::Drain,
            "heartbeat" => Msg::Heartbeat {
                lease: get_u64(j, "lease")?,
            },
            "event" => Msg::Event {
                event: Event::from_json(j.get("event")?)?,
            },
            "complete" => Msg::Complete {
                lease: get_u64(j, "lease")?,
                idx: get_u64(j, "idx")?,
                run_id: get_str(j, "run_id")?,
                attempts: get_u64(j, "attempts")?,
                degraded: get_bool(j, "degraded")?,
                csv: get_str(j, "csv")?,
            },
            "failed" => Msg::Failed {
                lease: get_u64(j, "lease")?,
                idx: get_u64(j, "idx")?,
                run_id: get_str(j, "run_id")?,
                attempts: get_u64(j, "attempts")?,
                class: get_str(j, "class")?,
                error: get_str(j, "error")?,
            },
            other => {
                return Err(Error::Protocol(format!("unknown fabric frame '{other}'")));
            }
        })
    }

    /// Parse one wire line.
    pub fn parse(line: &str) -> Result<Msg> {
        Msg::from_json(&Json::parse(line)?)
    }
}

/// Write one framed message (line + flush).
pub(crate) fn write_msg(w: &mut impl Write, msg: &Msg) -> std::io::Result<()> {
    let mut line = msg.to_json().to_compact_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Fault-injection seam: write only the front half of the frame and no
/// newline — the half-written line a worker dying mid-send leaves on
/// the coordinator's socket.
pub(crate) fn write_torn(w: &mut impl Write, msg: &Msg) -> std::io::Result<()> {
    let line = msg.to_json().to_compact_string();
    w.write_all(&line.as_bytes()[..line.len() / 2])?;
    w.flush()
}

/// What one read attempt produced.
#[derive(Debug)]
pub(crate) enum LineRead {
    /// A complete frame line (newline stripped).
    Line(String),
    /// The read timeout expired with no complete line buffered — the
    /// peer is quiet, not gone.
    TimedOut,
    /// The connection ended.  `torn` = bytes of a half-written frame
    /// were left behind (the peer died mid-send).
    Eof { torn: bool },
}

/// A newline framer that survives read timeouts: partial bytes stay
/// buffered across [`LineRead::TimedOut`] returns, so a frame split
/// across two reads (or interrupted by the socket timeout the
/// coordinator uses to poll its stop flag) reassembles intact.
#[derive(Default)]
pub(crate) struct LineReader {
    buf: Vec<u8>,
}

impl LineReader {
    pub(crate) fn new() -> LineReader {
        LineReader::default()
    }

    pub(crate) fn read_line(&mut self, stream: &mut TcpStream) -> LineRead {
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let rest = self.buf.split_off(pos + 1);
                let mut line = std::mem::replace(&mut self.buf, rest);
                line.pop(); // the newline
                return LineRead::Line(String::from_utf8_lossy(&line).into_owned());
            }
            let mut chunk = [0u8; 4096];
            match stream.read(&mut chunk) {
                Ok(0) => {
                    return LineRead::Eof {
                        torn: !self.buf.is_empty(),
                    };
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    return LineRead::TimedOut;
                }
                // reset/abort mid-frame: the peer is gone
                Err(_) => return LineRead::Eof { torn: true },
            }
        }
    }
}

/// FNV-1a over the campaign fingerprint's compact form — the shape
/// token the handshake compares, derived from exactly the fields the
/// ledger header binds.
pub fn spec_hash(spec: &SupervisedCampaignSpec) -> String {
    let s = crate::pipeline::supervisor::campaign_fingerprint(spec).to_compact_string();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::telemetry::EventKind;

    fn round_trip(msg: Msg) {
        let line = msg.to_json().to_compact_string();
        assert!(!line.contains('\n'), "one line per frame: {line}");
        assert_eq!(Msg::parse(&line).unwrap(), msg);
    }

    #[test]
    fn every_frame_round_trips() {
        round_trip(Msg::Hello {
            worker: "w1".into(),
            spec_hash: "00ff".into(),
        });
        round_trip(Msg::Welcome {
            heartbeat_ms: 25,
            lease_ttl_ms: 150,
        });
        round_trip(Msg::Refuse {
            reason: "different campaign shape".into(),
        });
        round_trip(Msg::Request);
        round_trip(Msg::Lease {
            lease: 9,
            idx: 4,
            attempt: 2,
        });
        round_trip(Msg::Wait { ms: 50 });
        round_trip(Msg::Drain);
        round_trip(Msg::Heartbeat { lease: 9 });
        round_trip(Msg::Event {
            event: Event {
                t_us: 7,
                kind: EventKind::LedgerTransition {
                    run_id: "f-e0[0]".into(),
                    state: "running".into(),
                },
            },
        });
        round_trip(Msg::Failed {
            lease: 9,
            idx: 4,
            run_id: "f-e0[4]".into(),
            attempts: 3,
            class: "permanent".into(),
            error: "bad config".into(),
        });
    }

    #[test]
    fn csv_payload_survives_json_framing() {
        // the whole point of string escaping: a multi-line CSV rides
        // one wire line and comes back byte-identical
        let csv = "t,speed,flow\n0.0,27.5,1200\n0.1,27.4,1199\n";
        let msg = Msg::Complete {
            lease: 3,
            idx: 1,
            run_id: "f-e0[1]".into(),
            attempts: 1,
            degraded: false,
            csv: csv.into(),
        };
        let line = msg.to_json().to_compact_string();
        assert!(!line.contains('\n'));
        match Msg::parse(&line).unwrap() {
            Msg::Complete { csv: back, .. } => assert_eq!(back, csv),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn unknown_tag_and_missing_field_are_protocol_errors() {
        assert!(Msg::parse(r#"{"msg":"teleport"}"#).is_err());
        assert!(Msg::parse(r#"{"msg":"lease","lease":1}"#).is_err());
        assert!(Msg::parse("not json").is_err());
    }

    #[test]
    fn line_reader_reassembles_split_frames_across_timeouts() {
        use std::io::Write;
        use std::net::TcpListener;
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            s.write_all(b"{\"msg\":\"req").unwrap();
            s.flush().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(60));
            s.write_all(b"uest\"}\n{\"msg\":\"drain\"}\n{\"half").unwrap();
            s.flush().unwrap();
            // dies here: the trailing bytes are a torn frame
        });
        let (mut stream, _) = listener.accept().unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_millis(20)))
            .unwrap();
        let mut reader = LineReader::new();
        let mut lines = Vec::new();
        let mut timeouts = 0;
        let torn = loop {
            match reader.read_line(&mut stream) {
                LineRead::Line(l) => lines.push(l),
                LineRead::TimedOut => timeouts += 1,
                LineRead::Eof { torn } => break torn,
            }
        };
        writer.join().unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(Msg::parse(&lines[0]).unwrap(), Msg::Request);
        assert_eq!(Msg::parse(&lines[1]).unwrap(), Msg::Drain);
        assert!(timeouts >= 1, "the split frame must ride over a timeout");
        assert!(torn, "trailing half-frame must be flagged torn");
    }

    #[test]
    fn spec_hash_is_shape_sensitive() {
        use crate::pipeline::{SupervisedCampaignSpec, SupervisorSpec};
        let spec = |seed: u64| SupervisedCampaignSpec {
            name: "h".into(),
            nodes: 1,
            slots_per_node: 2,
            epochs: 1,
            horizon_s: 2.0,
            capacity: 64,
            seed,
            matrix: None,
            supervisor: SupervisorSpec::default(),
            ledger_dir: std::env::temp_dir(),
            retry_failed: false,
            stop_after_runs: None,
        };
        assert_eq!(spec_hash(&spec(1)), spec_hash(&spec(1)));
        assert_ne!(spec_hash(&spec(1)), spec_hash(&spec(2)));
    }
}
