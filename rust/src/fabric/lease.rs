//! Lease bookkeeping for the coordinator's out-of-process reaper.
//!
//! A lease binds one campaign run index to one worker connection for a
//! bounded wall-clock window.  Heartbeats extend the window; a worker
//! that stops beating — killed process, dropped link, wedged host —
//! loses the lease when the reaper sweeps, and the run index goes back
//! on the dispatch queue.  This is the fabric's analogue of the local
//! supervisor's watchdogs: enforcement lives *outside* the process
//! doing the work, so no failure mode of the worker can disable it.
//!
//! Every method that touches time takes an explicit `now: Instant` so
//! the expiry logic is a pure function of its inputs and unit-testable
//! without sleeping.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// One outstanding lease.
#[derive(Debug, Clone)]
pub struct Lease {
    /// Monotonic lease id — never reused within one coordinator.
    pub id: u64,
    /// Campaign run index this lease covers.
    pub idx: u64,
    /// Run id (for the ledger and telemetry).
    pub run_id: String,
    /// Connection-unique worker key (`name#conn`): a reconnecting
    /// worker gets a fresh key, so a stale handler can never revoke
    /// the new connection's leases.
    pub worker: String,
    /// Fabric-level dispatch count for this idx (1-based; re-dispatch
    /// after expiry increments it).
    pub attempt: u32,
    /// When the lease was granted (walltime accounting).
    pub granted: Instant,
    /// Expiry deadline; heartbeats push it forward.
    pub deadline: Instant,
}

/// The coordinator's table of outstanding leases.
pub struct LeaseTable {
    ttl: Duration,
    next_id: u64,
    live: HashMap<u64, Lease>,
    /// idx → dispatches so far (survives expiry: attempt numbers keep
    /// rising across re-dispatches, matching the ledger's `attempt`).
    dispatches: HashMap<u64, u32>,
}

impl LeaseTable {
    pub fn new(ttl: Duration) -> LeaseTable {
        LeaseTable {
            ttl,
            next_id: 0,
            live: HashMap::new(),
            dispatches: HashMap::new(),
        }
    }

    /// Grant a lease on `idx` to `worker`, deadline `now + ttl`.
    pub fn grant(&mut self, idx: u64, run_id: &str, worker: &str, now: Instant) -> Lease {
        self.next_id += 1;
        let attempt = {
            let n = self.dispatches.entry(idx).or_insert(0);
            *n += 1;
            *n
        };
        let lease = Lease {
            id: self.next_id,
            idx,
            run_id: run_id.to_string(),
            worker: worker.to_string(),
            attempt,
            granted: now,
            deadline: now + self.ttl,
        };
        self.live.insert(lease.id, lease.clone());
        lease
    }

    /// Extend a lease's deadline.  Returns false for an unknown id —
    /// the lease was already reaped (the worker is a zombie) or never
    /// existed.
    pub fn heartbeat(&mut self, id: u64, now: Instant) -> bool {
        match self.live.get_mut(&id) {
            Some(lease) => {
                lease.deadline = now + self.ttl;
                true
            }
            None => false,
        }
    }

    /// Remove and return a lease (completion or terminal failure).
    pub fn release(&mut self, id: u64) -> Option<Lease> {
        self.live.remove(&id)
    }

    /// Remove and return every lease past its deadline — the reaper's
    /// sweep.  The caller re-queues the indices.
    pub fn expired(&mut self, now: Instant) -> Vec<Lease> {
        let ids: Vec<u64> = self
            .live
            .values()
            .filter(|l| l.deadline <= now)
            .map(|l| l.id)
            .collect();
        let mut out: Vec<Lease> = ids.iter().filter_map(|id| self.live.remove(id)).collect();
        out.sort_by_key(|l| l.id);
        out
    }

    /// Remove and return every lease held by `worker` — the instant
    /// revocation path when a connection drops (faster than waiting
    /// out the TTL).
    pub fn revoke_worker(&mut self, worker: &str) -> Vec<Lease> {
        let ids: Vec<u64> = self
            .live
            .values()
            .filter(|l| l.worker == worker)
            .map(|l| l.id)
            .collect();
        let mut out: Vec<Lease> = ids.iter().filter_map(|id| self.live.remove(id)).collect();
        out.sort_by_key(|l| l.id);
        out
    }

    /// Outstanding lease count.
    pub fn len(&self) -> usize {
        self.live.len()
    }

    pub fn is_empty(&self) -> bool {
        self.live.is_empty()
    }

    /// The lease currently covering `idx`, if any.
    pub fn holding(&self, idx: u64) -> Option<&Lease> {
        self.live.values().find(|l| l.idx == idx)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn table() -> LeaseTable {
        LeaseTable::new(Duration::from_millis(100))
    }

    #[test]
    fn grant_heartbeat_release_lifecycle() {
        let mut t = table();
        let now = Instant::now();
        let a = t.grant(0, "c-e0[0]", "w1#1", now);
        let b = t.grant(1, "c-e0[1]", "w1#1", now);
        assert_eq!((a.id, a.attempt), (1, 1));
        assert_eq!((b.id, b.attempt), (2, 1));
        assert_eq!(t.len(), 2);

        // heartbeat at +80ms pushes the deadline past the +100ms sweep
        assert!(t.heartbeat(a.id, now + Duration::from_millis(80)));
        let reaped = t.expired(now + Duration::from_millis(120));
        assert_eq!(reaped.len(), 1, "only the silent lease expires");
        assert_eq!(reaped[0].idx, 1);

        assert_eq!(t.release(a.id).unwrap().idx, 0);
        assert!(t.is_empty());
        assert!(!t.heartbeat(a.id, now), "released lease is unknown");
    }

    #[test]
    fn redispatch_after_expiry_increments_the_attempt() {
        let mut t = table();
        let now = Instant::now();
        let first = t.grant(3, "c-e0[3]", "w1#1", now);
        assert_eq!(first.attempt, 1);
        let reaped = t.expired(now + Duration::from_millis(200));
        assert_eq!(reaped.len(), 1);
        let second = t.grant(3, "c-e0[3]", "w2#1", now + Duration::from_millis(200));
        assert_eq!(second.attempt, 2, "dispatch count survives expiry");
        assert_ne!(second.id, first.id, "lease ids are never reused");
    }

    #[test]
    fn revoke_worker_takes_only_that_connections_leases() {
        let mut t = table();
        let now = Instant::now();
        t.grant(0, "c-e0[0]", "w1#1", now);
        t.grant(1, "c-e0[1]", "w1#2", now); // same name, newer connection
        t.grant(2, "c-e0[2]", "w2#1", now);
        let revoked = t.revoke_worker("w1#1");
        assert_eq!(revoked.len(), 1);
        assert_eq!(revoked[0].idx, 0);
        assert_eq!(t.len(), 2, "w1#2 and w2#1 keep their leases");
        assert!(t.holding(1).is_some());
    }
}
