//! The fabric worker: dials a coordinator, runs leased instances
//! through the local run supervisor, streams results home.
//!
//! A worker holds the same [`SupervisedCampaignSpec`] the coordinator
//! does — the handshake proves it via the spec hash — so a lease only
//! has to name a run *index*: [`plan_run`] materializes the identical
//! scenario on any worker from `(spec, idx)` alone.  Inside a lease the
//! worker is exactly the single-process driver: a [`PortLease`] for the
//! TraCI server, [`supervise_instance`] for containment / retry /
//! watchdogs / degradation, and the finished CSV rides back inline.
//!
//! A heartbeat thread keeps the lease alive *while the run executes*,
//! so only true worker death — not slowness — trips the coordinator's
//! reaper.  Test seams inject exactly those deaths: transport faults
//! (dropped connections, torn frames, duplicated completions) and
//! process kills ([`WorkerKill`]), including the zombie that stops
//! beating, sleeps past the TTL, and reports late into the duplicate
//! guard.

use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::protocol::{spec_hash, write_msg, write_torn, LineRead, LineReader, Msg};
use crate::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use crate::display::DisplayRegistry;
use crate::pipeline::faults::{FaultPlan, FaultSite};
use crate::pipeline::ports::PortLease;
use crate::pipeline::supervisor::{
    classify, instance_config, plan_run, supervise_instance, SupervisedCampaignSpec,
};
use crate::pipeline::PhysicsEngine;
use crate::scenario::FamilyRegistry;
use crate::telemetry::{self, Event, EventSink};
use crate::Result;

/// Process-kill seams for the soak tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerKill {
    /// Run to drain.
    Never,
    /// Die abruptly (connection drops, nothing reported) when the
    /// (n+1)-th lease arrives — after `n` successful completions.
    DieAfter(u64),
    /// Zombie mode: after `n` completions, finish the next run but stop
    /// heartbeating, sleep past the lease TTL, and only then send the
    /// (now unwelcome) completion — the reaper re-dispatches meanwhile,
    /// and whichever result lands second hits the duplicate guard.
    ZombieAfter(u64),
}

/// One worker's standing configuration.
#[derive(Debug, Clone)]
pub struct WorkerConfig {
    /// Worker name (the coordinator suffixes a connection counter).
    pub name: String,
    /// Coordinator address, `host:port`.
    pub addr: String,
    /// The campaign — must hash-match the coordinator's or the
    /// handshake is refused.
    pub spec: SupervisedCampaignSpec,
    /// Forward locally emitted telemetry events over the fabric into a
    /// per-connection shard next to the coordinator's ledger.
    pub forward_events: bool,
    /// Re-dials after a failed connect or a dropped connection before
    /// giving up (a stopped coordinator is a normal way to finish).
    pub reconnect_attempts: u32,
    pub reconnect_delay_ms: u64,
    /// Transport-fault schedule (FabricDrop / FabricTorn /
    /// FabricDuplicate sites; None in production).
    pub transport_faults: Option<FaultPlan>,
    pub kill: WorkerKill,
}

impl WorkerConfig {
    /// Production defaults for a worker of `spec` at `addr`.
    pub fn new(
        name: impl Into<String>,
        addr: impl Into<String>,
        spec: SupervisedCampaignSpec,
    ) -> WorkerConfig {
        WorkerConfig {
            name: name.into(),
            addr: addr.into(),
            spec,
            forward_events: false,
            reconnect_attempts: 8,
            reconnect_delay_ms: 200,
            transport_faults: None,
            kill: WorkerKill::Never,
        }
    }
}

/// How a worker session ended.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WorkerOutcome {
    /// Completions successfully reported.
    pub completions: u64,
    /// Terminal failures reported.
    pub failures: u64,
    /// Coordinator said the campaign is settled.
    pub drained: bool,
    /// A [`WorkerKill`] seam fired.
    pub died: bool,
    /// Handshake refusal reason, if refused.
    pub refused: Option<String>,
}

/// Why one connection session ended (worker-internal).
enum SessionEnd {
    Drained,
    Refused(String),
    Died,
    /// Connection lost (coordinator gone, injected drop/tear, I/O
    /// error) — re-dial if attempts remain.
    Lost,
}

/// Uninstalls the forwarding sink even on early returns.
struct SinkGuard(Arc<dyn EventSink>);

impl Drop for SinkGuard {
    fn drop(&mut self) {
        telemetry::uninstall(&self.0);
    }
}

/// Forwards every locally emitted event over the fabric connection.
/// Shares the protocol write lock, so forwarded lines never interleave
/// with heartbeats or result frames.
struct ForwardSink {
    writer: Arc<Mutex<TcpStream>>,
}

impl EventSink for ForwardSink {
    fn emit(&self, ev: &Event) {
        let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
        // telemetry must never fail the run; a lost event is fine
        let _ = write_msg(&mut *w, &Msg::Event { event: ev.clone() });
    }

    fn flush(&self) {}
}

/// Dial the coordinator and work until drained, killed, or out of
/// re-dials.  Every error a *run* can produce is absorbed into the
/// protocol (reported as a remote failure); an `Err` from here means
/// the worker environment itself could not be built.
pub fn run_worker(cfg: &WorkerConfig, physics: &PhysicsEngine) -> Result<WorkerOutcome> {
    let displays = DisplayRegistry::new();
    let sif = build_webots_hpc_image(BuildHost::PersonalComputer)?;
    let env = ExecEnv::new(sif).bind("/tmp", "/tmp");
    let registry = FamilyRegistry::builtin();
    let hash = spec_hash(&cfg.spec);

    let mut out = WorkerOutcome::default();
    let mut redials = 0u32;
    loop {
        let stream = match TcpStream::connect(&cfg.addr) {
            Ok(s) => s,
            Err(_) => {
                if redials >= cfg.reconnect_attempts {
                    return Ok(out);
                }
                redials += 1;
                std::thread::sleep(Duration::from_millis(cfg.reconnect_delay_ms));
                continue;
            }
        };
        let end = serve_session(stream, cfg, physics, &displays, &env, &registry, &hash, &mut out);
        match end {
            SessionEnd::Drained => {
                out.drained = true;
                return Ok(out);
            }
            SessionEnd::Refused(reason) => {
                out.refused = Some(reason);
                return Ok(out);
            }
            SessionEnd::Died => {
                out.died = true;
                return Ok(out);
            }
            SessionEnd::Lost => {
                if redials >= cfg.reconnect_attempts {
                    return Ok(out);
                }
                redials += 1;
                std::thread::sleep(Duration::from_millis(cfg.reconnect_delay_ms));
            }
        }
    }
}

/// Wait (bounded) for the next coordinator frame.
fn read_reply(reader: &mut LineReader, stream: &mut TcpStream, deadline: Instant) -> Option<Msg> {
    loop {
        match reader.read_line(stream) {
            LineRead::Line(l) => return Msg::parse(&l).ok(),
            LineRead::TimedOut => {
                if Instant::now() >= deadline {
                    return None;
                }
            }
            LineRead::Eof { .. } => return None,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_session(
    stream: TcpStream,
    cfg: &WorkerConfig,
    physics: &PhysicsEngine,
    displays: &DisplayRegistry,
    env: &ExecEnv,
    registry: &FamilyRegistry,
    hash: &str,
    out: &mut WorkerOutcome,
) -> SessionEnd {
    stream.set_nodelay(true).ok();
    if stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .is_err()
        || stream.set_write_timeout(Some(Duration::from_secs(2))).is_err()
    {
        return SessionEnd::Lost;
    }
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return SessionEnd::Lost,
    };
    let mut read_stream = stream;
    let mut reader = LineReader::new();

    let send = |msg: &Msg| -> std::io::Result<()> {
        let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
        write_msg(&mut *w, msg)
    };

    if send(&Msg::Hello {
        worker: cfg.name.clone(),
        spec_hash: hash.to_string(),
    })
    .is_err()
    {
        return SessionEnd::Lost;
    }
    let (heartbeat_ms, lease_ttl_ms) = match read_reply(
        &mut reader,
        &mut read_stream,
        Instant::now() + Duration::from_secs(5),
    ) {
        Some(Msg::Welcome {
            heartbeat_ms,
            lease_ttl_ms,
        }) => (heartbeat_ms, lease_ttl_ms),
        Some(Msg::Refuse { reason }) => return SessionEnd::Refused(reason),
        _ => return SessionEnd::Lost,
    };

    // the heartbeat thread: beats for whichever lease is current, even
    // while the main thread is deep inside a long supervised run
    let current_lease: Arc<Mutex<Option<u64>>> = Arc::new(Mutex::new(None));
    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_handle = {
        let writer = Arc::clone(&writer);
        let current = Arc::clone(&current_lease);
        let stop = Arc::clone(&hb_stop);
        let interval = Duration::from_millis(heartbeat_ms.max(1));
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(interval);
                let lease = *current.lock().unwrap_or_else(|p| p.into_inner());
                if let Some(lease) = lease {
                    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
                    let _ = write_msg(&mut *w, &Msg::Heartbeat { lease });
                }
            }
        })
    };
    let _hb_guard = HeartbeatGuard {
        stop: Arc::clone(&hb_stop),
        handle: Some(hb_handle),
    };

    let _forward_guard = if cfg.forward_events {
        let sink: Arc<dyn EventSink> = Arc::new(ForwardSink {
            writer: Arc::clone(&writer),
        });
        telemetry::install(Arc::clone(&sink));
        Some(SinkGuard(sink))
    } else {
        None
    };

    let set_current = |v: Option<u64>| {
        *current_lease.lock().unwrap_or_else(|p| p.into_inner()) = v;
    };

    loop {
        if send(&Msg::Request).is_err() {
            return SessionEnd::Lost;
        }
        let reply = read_reply(
            &mut reader,
            &mut read_stream,
            Instant::now() + Duration::from_secs(10),
        );
        let (lease, idx, attempt) = match reply {
            Some(Msg::Lease { lease, idx, attempt }) => (lease, idx, attempt),
            Some(Msg::Wait { ms }) => {
                std::thread::sleep(Duration::from_millis(ms.min(1000)));
                continue;
            }
            Some(Msg::Drain) => return SessionEnd::Drained,
            _ => return SessionEnd::Lost,
        };

        // hard-kill seam: the process dies the instant the (n+1)-th
        // lease lands — nothing is released, nothing is reported; the
        // coordinator learns from the dropped connection / the reaper
        if let WorkerKill::DieAfter(n) = cfg.kill {
            if out.completions >= n {
                return SessionEnd::Died;
            }
        }

        let plan = match plan_run(&cfg.spec, registry, idx) {
            Ok(p) => p,
            Err(e) => {
                let run_id = format!("{}-idx{idx}", cfg.spec.name);
                let _ = send(&Msg::Failed {
                    lease,
                    idx,
                    run_id,
                    attempts: 1,
                    class: "permanent".into(),
                    error: e.to_string(),
                });
                out.failures += 1;
                continue;
            }
        };
        set_current(Some(lease));
        let report = match PortLease::acquire() {
            Ok(port_lease) => {
                let icfg = instance_config(&cfg.spec, &plan, port_lease.port());
                supervise_instance(&icfg, displays, env, physics, &cfg.spec.supervisor)
            }
            Err(e) => {
                set_current(None);
                let _ = send(&Msg::Failed {
                    lease,
                    idx,
                    run_id: plan.run_id.clone(),
                    attempts: 1,
                    class: classify(&e).name().into(),
                    error: e.to_string(),
                });
                out.failures += 1;
                continue;
            }
        };

        match report.outcome {
            Ok(r) => {
                let msg = Msg::Complete {
                    lease,
                    idx,
                    run_id: plan.run_id.clone(),
                    attempts: report.attempts as u64,
                    degraded: report.degraded,
                    csv: r.dataset.to_csv(),
                };

                // zombie seam: stop beating while still holding the
                // lease, sleep past the TTL (the reaper revokes and
                // re-dispatches meanwhile), then report late
                if let WorkerKill::ZombieAfter(n) = cfg.kill {
                    if out.completions >= n {
                        set_current(None);
                        std::thread::sleep(Duration::from_millis(lease_ttl_ms * 3));
                        let _ = send(&msg);
                        return SessionEnd::Died;
                    }
                }
                set_current(None);

                // transport-fault seams, redrawn per fabric dispatch:
                // a retransmitted slot isn't doomed to the same fault
                let fires = |site: FaultSite| {
                    cfg.transport_faults
                        .as_ref()
                        .is_some_and(|p| p.fires(site, plan.seed, attempt as u32))
                };
                if fires(FaultSite::FabricDrop) {
                    // vanish mid-report: the run finished locally but
                    // the result never leaves this process
                    return SessionEnd::Lost;
                }
                if fires(FaultSite::FabricTorn) {
                    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
                    let _ = write_torn(&mut *w, &msg);
                    drop(w);
                    return SessionEnd::Lost;
                }
                if send(&msg).is_err() {
                    return SessionEnd::Lost;
                }
                out.completions += 1;
                if fires(FaultSite::FabricDuplicate) {
                    // retransmission: the duplicate guard absorbs it
                    let _ = send(&msg);
                }
            }
            Err(e) => {
                set_current(None);
                if send(&Msg::Failed {
                    lease,
                    idx,
                    run_id: plan.run_id.clone(),
                    attempts: report.attempts as u64,
                    class: classify(&e).name().into(),
                    error: e.to_string(),
                })
                .is_err()
                {
                    return SessionEnd::Lost;
                }
                out.failures += 1;
            }
        }
    }
}

/// Stops and joins the heartbeat thread on every exit path.
struct HeartbeatGuard {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Drop for HeartbeatGuard {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
