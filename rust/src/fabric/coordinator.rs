//! The campaign coordinator: sole owner of the ledger, lessor of work.
//!
//! One coordinator process drives a whole distributed campaign:
//!
//! * it replays/extends the crash-safe [`CampaignLedger`] exactly like
//!   the single-process driver (kill the coordinator, start a new one
//!   on the same ledger dir, and the campaign resumes),
//! * it hands out **leases** on `(epoch, slot)` coordinates — workers
//!   materialize the runs themselves from the shared spec, so the wire
//!   never carries scenario payloads,
//! * its **reaper thread** enforces heartbeat deadlines from outside
//!   every worker process: a worker that dies, wedges, or drops its
//!   link loses the lease and the index is re-dispatched,
//! * completions are settled through the ledger's duplicate guard, so
//!   a zombie worker's late result for an already-settled run is
//!   rejected idempotently — re-dispatch can never produce duplicate
//!   run_ids in the aggregate,
//! * the final dataset is assembled by the *same* ledger+disk walk the
//!   single-process driver uses ([`assemble_aggregate`]), which is what
//!   makes the distributed aggregate byte-identical to the local one.
//!
//! # Lock discipline (xtask lint: `lock-discipline`)
//!
//! Two mutexes, never nested:
//!
//! * the **dispatch mutex** ([`Shared`]) serializes lease grants,
//!   queue movement, and stats.  Every worker connection and the
//!   reaper contend on it, so nothing blocking may run under it — no
//!   ledger fsync, no CSV publish, no socket write, no telemetry
//!   emit.  `cargo run -p xtask -- lint` rejects this file otherwise.
//! * the **ledger mutex** serializes the append-fsync file I/O alone.
//!
//! Settlement therefore runs in three phases: *claim* the run under
//! the dispatch mutex (duplicate guard + `settling` marker), do the
//! durable work under the ledger mutex only, then *finalize* the
//! bookkeeping under the dispatch mutex again.  The `settling` set and
//! the `dispatching` counter keep the accept loop from declaring the
//! campaign settled while a claim's I/O is still in flight — the
//! same in-limbo race PR 8 closed for revoke/requeue, held machine-
//! checked instead of reviewer-checked.

use std::collections::{HashSet, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::lease::LeaseTable;
use super::protocol::{spec_hash, write_msg, LineRead, LineReader, Msg};
use crate::output::CampaignDataset;
use crate::pipeline::ledger::{CampaignLedger, LedgerState};
use crate::pipeline::supervisor::{
    assemble_aggregate, campaign_fingerprint, grid, plan_run, publish_run_csv, ErrorClass,
    RobustnessStats, SupervisedCampaignSpec,
};
use crate::pipeline::CampaignResult;
use crate::scenario::FamilyRegistry;
use crate::telemetry::{self, EventKind, EventSink, JsonlSink};
use crate::{Error, Result};

/// Fabric-side knobs (the campaign itself comes from
/// [`SupervisedCampaignSpec`]).
#[derive(Debug, Clone)]
pub struct FabricConfig {
    /// TCP port to listen on (0 = OS-assigned; read it back with
    /// [`Coordinator::port`]).
    pub port: u16,
    /// Heartbeat cadence workers are told to keep [ms].
    pub heartbeat_ms: u64,
    /// Lease TTL the reaper enforces [ms] — a lease silent this long is
    /// revoked and its run re-dispatched.  Must comfortably exceed the
    /// heartbeat interval.
    pub lease_ttl_ms: u64,
    /// Test seam: stop the coordinator (abandoning everything in
    /// flight) after accepting this many completions this session —
    /// simulates a mid-campaign coordinator kill.
    pub stop_after_completions: Option<u64>,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            port: 0,
            heartbeat_ms: 500,
            lease_ttl_ms: 3000,
            stop_after_completions: None,
        }
    }
}

/// Fabric-level accounting for one coordinator session.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FabricStats {
    /// Worker handshakes accepted (a reconnect counts again).
    pub workers_joined: u64,
    /// Worker connections ended (drain, drop, kill, torn frame).
    pub workers_left: u64,
    /// Handshakes refused for a mismatched campaign shape.
    pub workers_refused: u64,
    /// Leases granted (re-dispatches included).
    pub leases_granted: u64,
    /// Leases revoked — by the reaper (missed heartbeats) or instantly
    /// when the holder's connection dropped.
    pub leases_expired: u64,
    /// Completions accepted and settled into the ledger.
    pub completions_accepted: u64,
    /// Completions rejected by the duplicate guard (zombie or
    /// retransmitted results for already-settled runs).
    pub completions_rejected: u64,
    /// Terminal failures reported by workers.
    pub remote_failures: u64,
}

/// What one coordinator session produced.
#[derive(Debug)]
pub struct FabricOutcome {
    pub result: CampaignResult,
    /// Aggregate dataset from the shared ledger+disk walk — identical
    /// to the single-process assembly for the same spec and seed.
    pub dataset: CampaignDataset,
    /// True when the session ended with unsettled work (coordinator
    /// kill seam) — re-bind on the same ledger dir to resume.
    pub interrupted: bool,
    pub fabric: FabricStats,
}

/// Mutable dispatch state every connection handler and the reaper
/// share.  This mutex serializes *decisions only* — the durable ledger
/// lives behind its own mutex and is never touched while this one is
/// held (see the module-level lock-discipline notes).
struct Shared {
    /// Unsettled run indices awaiting dispatch.  Invariant: every
    /// unsettled index is in the queue, covered by a live lease, mid
    /// dispatch (`dispatching`), or mid settlement (`settling`).
    queue: VecDeque<u64>,
    leases: LeaseTable,
    stats: RobustnessStats,
    fabric: FabricStats,
    walltimes_s: Vec<f64>,
    accepted_this_session: u64,
    stopping: bool,
    /// First unrecoverable handler error (ledger write failure).
    fatal: Option<String>,
    /// run_ids with a durable `completed` ledger record — the
    /// in-memory side of the duplicate guard, so settlement decisions
    /// never read the ledger file under this mutex.
    completed: HashSet<String>,
    /// run_ids claimed by an in-flight settlement whose ledger/CSV I/O
    /// is running outside this mutex.  A second result for the same
    /// run is a duplicate while its claim is open, and the accept loop
    /// must not declare the campaign settled while any claim is open.
    settling: HashSet<String>,
    /// Indices popped from the queue whose lease grant has not landed
    /// yet (the handler is materializing the plan outside this mutex).
    dispatching: u32,
}

impl Shared {
    /// True when no work is queued, leased, mid-dispatch, or mid
    /// settlement — the accept loop's exit predicate.  Every phase of
    /// the dispatch/settle protocols keeps its run covered by exactly
    /// one of these four, so this can never report "settled" while a
    /// claim's durable I/O is still in flight.
    fn settled_idle(&self) -> bool {
        self.queue.is_empty()
            && self.leases.is_empty()
            && self.settling.is_empty()
            && self.dispatching == 0
    }

    /// Claim `run_id` for settlement.  Returns false when the run is
    /// already settled or another settlement of it is in flight — the
    /// duplicate-guard decision, made without touching the ledger.
    fn begin_settlement(&mut self, run_id: &str) -> bool {
        if self.completed.contains(run_id) || self.settling.contains(run_id) {
            return false;
        }
        self.settling.insert(run_id.to_string());
        true
    }

    fn settle_check(&mut self, stop_after: Option<u64>) {
        if let Some(stop) = stop_after {
            if self.accepted_this_session >= stop {
                self.stopping = true;
            }
        }
    }
}

fn lock(shared: &Mutex<Shared>) -> MutexGuard<'_, Shared> {
    shared.lock().unwrap_or_else(|p| p.into_inner())
}

/// The ledger's own mutex — serializes append-fsync I/O only.  Never
/// call this while a [`lock`] guard is live (the xtask lint enforces
/// the ordering).
fn lock_ledger(ledger: &Mutex<CampaignLedger>) -> MutexGuard<'_, CampaignLedger> {
    ledger.lock().unwrap_or_else(|p| p.into_inner())
}

/// A bound, resumable campaign coordinator.
pub struct Coordinator {
    spec: Arc<SupervisedCampaignSpec>,
    cfg: FabricConfig,
    listener: TcpListener,
    port: u16,
    runs_dir: PathBuf,
    hash: String,
    shared: Arc<Mutex<Shared>>,
    ledger: Arc<Mutex<CampaignLedger>>,
}

impl Coordinator {
    /// Open (or resume) the campaign ledger and bind the fabric port.
    /// The dispatch queue is seeded with every run the ledger does not
    /// already settle — the same resume predicate the single-process
    /// driver applies.
    pub fn bind(spec: SupervisedCampaignSpec, cfg: FabricConfig) -> Result<Coordinator> {
        let mut ledger = CampaignLedger::open(spec.ledger_dir.join("ledger.jsonl"))?;
        ledger.ensure_header(&campaign_fingerprint(&spec))?;
        let runs_dir = spec.ledger_dir.join("runs");
        std::fs::create_dir_all(&runs_dir)?;

        let registry = FamilyRegistry::builtin();
        let mut queue = VecDeque::new();
        let mut stats = RobustnessStats::default();
        let mut completed = HashSet::new();
        for idx in 0..spec.total_runs() {
            let plan = plan_run(&spec, &registry, idx)?;
            let settled = match ledger.state(&plan.run_id).map(|e| &e.state) {
                Some(LedgerState::Completed { .. }) => Some(true),
                Some(LedgerState::Failed { class, .. })
                    if class.as_str() == ErrorClass::Permanent.name() && !spec.retry_failed =>
                {
                    Some(false)
                }
                _ => None,
            };
            match settled {
                Some(completed_run) => {
                    stats.runs += 1;
                    stats.resumed_skips += 1;
                    if completed_run {
                        stats.completed += 1;
                        completed.insert(plan.run_id.clone());
                    } else {
                        stats.failed += 1;
                    }
                }
                None => queue.push_back(idx),
            }
        }

        let listener = TcpListener::bind(("127.0.0.1", cfg.port))?;
        let port = listener.local_addr()?.port();
        listener.set_nonblocking(true)?;

        let hash = spec_hash(&spec);
        let shared = Shared {
            queue,
            leases: LeaseTable::new(Duration::from_millis(cfg.lease_ttl_ms)),
            stats,
            fabric: FabricStats::default(),
            walltimes_s: Vec::new(),
            accepted_this_session: 0,
            stopping: false,
            fatal: None,
            completed,
            settling: HashSet::new(),
            dispatching: 0,
        };
        Ok(Coordinator {
            spec: Arc::new(spec),
            cfg,
            listener,
            port,
            runs_dir,
            hash,
            shared: Arc::new(Mutex::new(shared)),
            ledger: Arc::new(Mutex::new(ledger)),
        })
    }

    /// The port workers dial.
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Serve until the campaign settles (or the kill seam fires), then
    /// assemble the aggregate from ledger + disk.
    pub fn run(self) -> Result<FabricOutcome> {
        let spec = self.spec;
        let cfg = self.cfg;
        let shared = self.shared;
        let ledger = self.ledger;

        if telemetry::enabled() {
            telemetry::emit(EventKind::CampaignBegin {
                name: spec.name.clone(),
                nodes: spec.nodes as u64,
                slots_per_node: spec.slots_per_node as u64,
                epochs: spec.epochs,
                runs: spec.total_runs(),
            });
        }

        // the reaper: lease-deadline enforcement outside every worker
        let reaper = {
            let shared = Arc::clone(&shared);
            let sweep = Duration::from_millis((cfg.lease_ttl_ms / 4).max(5));
            std::thread::spawn(move || loop {
                std::thread::sleep(sweep);
                // requeue decisions use the in-memory completed set, so
                // the whole sweep is pure bookkeeping; events fire
                // after the guard is gone
                let expired = {
                    let mut g = lock(&shared);
                    if g.stopping {
                        return;
                    }
                    let expired = g.leases.expired(Instant::now());
                    for lease in &expired {
                        if !g.completed.contains(&lease.run_id)
                            && !g.settling.contains(&lease.run_id)
                        {
                            g.queue.push_back(lease.idx);
                        }
                        g.fabric.leases_expired += 1;
                    }
                    expired
                };
                if telemetry::enabled() {
                    for lease in &expired {
                        telemetry::emit(EventKind::LeaseExpired {
                            run_id: lease.run_id.clone(),
                            worker: lease.worker.clone(),
                            lease: lease.id,
                        });
                    }
                }
            })
        };

        let mut handlers = Vec::new();
        let mut conn_seq = 0u64;
        loop {
            {
                let mut g = lock(&shared);
                if g.stopping {
                    break;
                }
                if g.settled_idle() {
                    g.stopping = true;
                    break;
                }
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    conn_seq += 1;
                    let ctx = ConnCtx {
                        shared: Arc::clone(&shared),
                        ledger: Arc::clone(&ledger),
                        spec: Arc::clone(&spec),
                        cfg: cfg.clone(),
                        runs_dir: self.runs_dir.clone(),
                        hash: self.hash.clone(),
                        conn_seq,
                    };
                    handlers.push(std::thread::spawn(move || handle_conn(stream, ctx)));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    lock(&shared).stopping = true;
                    let _ = reaper.join();
                    return Err(e.into());
                }
            }
        }
        lock(&shared).stopping = true;
        drop(self.listener);
        for h in handlers {
            let _ = h.join();
        }
        let _ = reaper.join();

        let shared = Arc::try_unwrap(shared)
            .map_err(|_| Error::Protocol("fabric shared state still referenced".into()))?;
        let shared = shared.into_inner().unwrap_or_else(|p| p.into_inner());
        let ledger = Arc::try_unwrap(ledger)
            .map_err(|_| Error::Protocol("fabric ledger still referenced".into()))?;
        let ledger = ledger.into_inner().unwrap_or_else(|p| p.into_inner());
        if let Some(msg) = shared.fatal {
            return Err(Error::Config(format!("fabric coordinator: {msg}")));
        }
        let interrupted = !shared.settled_idle();

        if telemetry::enabled() {
            telemetry::emit(EventKind::CampaignEnd {
                name: spec.name.clone(),
                completed: shared.stats.completed,
                failed: shared.stats.failed,
            });
            telemetry::flush_all();
        }

        let registry = FamilyRegistry::builtin();
        let dataset = assemble_aggregate(&spec, &registry, &ledger, &self.runs_dir)?;
        let result = crate::pipeline::campaign::supervised_result(
            shared.stats,
            &shared.walltimes_s,
            &dataset,
            spec.nodes,
        );
        Ok(FabricOutcome {
            result,
            dataset,
            interrupted,
            fabric: shared.fabric,
        })
    }
}

/// Everything one connection handler needs.
struct ConnCtx {
    shared: Arc<Mutex<Shared>>,
    ledger: Arc<Mutex<CampaignLedger>>,
    spec: Arc<SupervisedCampaignSpec>,
    cfg: FabricConfig,
    runs_dir: PathBuf,
    hash: String,
    conn_seq: u64,
}

/// Serve one worker connection.  A ledger/CSV write failure is fatal
/// for the whole coordinator (the ledger is the source of truth); it
/// is recorded in `Shared::fatal` and stops the session.
fn handle_conn(mut stream: TcpStream, ctx: ConnCtx) {
    if let Err(e) = serve_worker(&mut stream, &ctx) {
        let mut g = lock(&ctx.shared);
        if g.fatal.is_none() {
            g.fatal = Some(e.to_string());
        }
        g.stopping = true;
    }
}

fn serve_worker(stream: &mut TcpStream, ctx: &ConnCtx) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(50)))
        .ok();
    stream
        .set_write_timeout(Some(Duration::from_secs(2)))
        .ok();
    let mut reader = LineReader::new();

    // handshake: the first frame must be Hello with the right shape
    let deadline = Instant::now() + Duration::from_secs(5);
    let hello = loop {
        match reader.read_line(stream) {
            LineRead::Line(l) => break Msg::parse(&l),
            LineRead::TimedOut => {
                if lock(&ctx.shared).stopping || Instant::now() >= deadline {
                    return Ok(());
                }
            }
            LineRead::Eof { .. } => return Ok(()),
        }
    };
    let worker = match hello {
        Ok(Msg::Hello { worker, spec_hash }) => {
            if spec_hash != ctx.hash {
                lock(&ctx.shared).fabric.workers_refused += 1;
                let _ = write_msg(
                    stream,
                    &Msg::Refuse {
                        reason: format!(
                            "worker '{worker}' built a different campaign shape \
                             (spec hash {spec_hash}, coordinator has {})",
                            ctx.hash
                        ),
                    },
                );
                return Ok(());
            }
            worker
        }
        _ => return Ok(()), // not a fabric worker; drop silently
    };
    // connection-unique key: a reconnect gets a fresh identity, so this
    // handler can never revoke a newer connection's leases on exit
    let key = format!("{worker}#{}", ctx.conn_seq);
    lock(&ctx.shared).fabric.workers_joined += 1;
    if telemetry::enabled() {
        telemetry::emit(EventKind::WorkerJoin {
            worker: key.clone(),
        });
    }
    if write_msg(
        stream,
        &Msg::Welcome {
            heartbeat_ms: ctx.cfg.heartbeat_ms,
            lease_ttl_ms: ctx.cfg.lease_ttl_ms,
        },
    )
    .is_err()
    {
        leave(ctx, &key, "handshake write failed");
        return Ok(());
    }

    let registry = FamilyRegistry::builtin();
    // forwarded telemetry lands in a per-connection shard next to the
    // ledger; `webots-hpc report` merges shards back into one stream
    let mut forward_sink: Option<JsonlSink> = None;

    let reason: String = loop {
        let msg = match reader.read_line(stream) {
            LineRead::Line(l) => match Msg::parse(&l) {
                Ok(m) => m,
                Err(_) => break "protocol error".into(),
            },
            LineRead::TimedOut => {
                if lock(&ctx.shared).stopping {
                    break "coordinator stopping".into();
                }
                continue;
            }
            LineRead::Eof { torn } => {
                break if torn {
                    "torn frame".into()
                } else {
                    "connection closed".into()
                };
            }
        };
        match msg {
            Msg::Request => {
                let reply = next_assignment(ctx, &registry, &key)?;
                if write_msg(stream, &reply).is_err() {
                    break "reply write failed".into();
                }
            }
            Msg::Heartbeat { lease } => {
                // an unknown lease id means the reaper already revoked
                // it; the worker finds out when it reports the result
                lock(&ctx.shared).leases.heartbeat(lease, Instant::now());
            }
            Msg::Complete {
                lease,
                idx,
                run_id,
                attempts,
                degraded,
                csv,
            } => {
                settle_completion(ctx, &key, lease, idx, &run_id, attempts, degraded, &csv)?;
            }
            Msg::Failed {
                lease,
                idx,
                run_id,
                attempts,
                class,
                error,
            } => {
                settle_failure(ctx, &key, lease, idx, &run_id, attempts, &class, &error)?;
            }
            Msg::Event { event } => {
                if forward_sink.is_none() {
                    let name: String = key
                        .chars()
                        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                        .collect();
                    forward_sink =
                        JsonlSink::append(ctx.spec.ledger_dir.join(format!("events-{name}.jsonl")))
                            .ok();
                }
                if let Some(sink) = &forward_sink {
                    // already stamped by the worker: append verbatim
                    sink.emit(&event);
                }
            }
            // frames only the coordinator sends — a confused peer
            Msg::Hello { .. }
            | Msg::Welcome { .. }
            | Msg::Refuse { .. }
            | Msg::Lease { .. }
            | Msg::Wait { .. }
            | Msg::Drain => break "protocol error".into(),
        }
    };

    // instant revocation: a dead connection doesn't wait out the TTL.
    // One critical section — revoke and re-queue must be atomic, or
    // the accept loop could observe "no queue, no leases" in between
    // and declare the campaign settled with this work in limbo.
    let revoked = {
        let mut g = lock(&ctx.shared);
        let revoked = g.leases.revoke_worker(&key);
        for lease in &revoked {
            if !g.completed.contains(&lease.run_id) && !g.settling.contains(&lease.run_id) {
                g.queue.push_back(lease.idx);
            }
            g.fabric.leases_expired += 1;
        }
        revoked
    };
    if telemetry::enabled() {
        for lease in &revoked {
            telemetry::emit(EventKind::LeaseExpired {
                run_id: lease.run_id.clone(),
                worker: key.clone(),
                lease: lease.id,
            });
        }
    }
    leave(ctx, &key, &reason);
    Ok(())
}

fn leave(ctx: &ConnCtx, key: &str, reason: &str) {
    lock(&ctx.shared).fabric.workers_left += 1;
    if telemetry::enabled() {
        telemetry::emit(EventKind::WorkerLeave {
            worker: key.to_string(),
            reason: reason.to_string(),
        });
    }
}

/// Pick the next frame to answer a work request with: a lease on the
/// head of the queue, Wait while everything is out on other leases, or
/// Drain when the campaign is settled / stopping.
///
/// Dispatch protocol: pop the index and raise `dispatching` under the
/// mutex, materialize the plan and write the durable `running` record
/// with the mutex released, grant the lease (and lower `dispatching`)
/// under the mutex again.  The counter keeps the popped index covered
/// so the accept loop cannot exit mid-dispatch.
fn next_assignment(ctx: &ConnCtx, registry: &FamilyRegistry, key: &str) -> Result<Msg> {
    let idx = {
        let mut g = lock(&ctx.shared);
        if g.stopping {
            return Ok(Msg::Drain);
        }
        match g.queue.pop_front() {
            Some(idx) => {
                g.dispatching += 1;
                idx
            }
            None => {
                return Ok(if g.settled_idle() {
                    Msg::Drain
                } else {
                    Msg::Wait {
                        ms: ctx.cfg.heartbeat_ms,
                    }
                });
            }
        }
    };
    // plan materialization is pure but not cheap — outside the mutex
    match plan_run(&ctx.spec, registry, idx) {
        Ok(plan) => {
            let lease = {
                let mut g = lock(&ctx.shared);
                g.dispatching -= 1;
                if g.stopping {
                    g.queue.push_front(idx);
                    return Ok(Msg::Drain);
                }
                let lease = g.leases.grant(idx, &plan.run_id, key, Instant::now());
                g.fabric.leases_granted += 1;
                lease
            };
            // the durable `running` record: the lease covers the index
            // while this fsync runs, so nothing is in limbo, and the
            // worker cannot race its own record — it learns about the
            // lease only from the reply frame written after this.
            lock_ledger(&ctx.ledger).mark_running(
                &plan.run_id,
                plan.epoch,
                plan.slot,
                lease.attempt,
            )?;
            if telemetry::enabled() {
                telemetry::emit(EventKind::RunBegin {
                    run_id: plan.run_id.clone(),
                    epoch: plan.epoch as u64,
                    slot: plan.slot as u64,
                    node: plan.node as u64,
                });
                telemetry::emit(EventKind::LeaseGrant {
                    run_id: plan.run_id,
                    worker: key.to_string(),
                    lease: lease.id,
                    attempt: lease.attempt as u64,
                });
            }
            Ok(Msg::Lease {
                lease: lease.id,
                idx,
                attempt: lease.attempt as u64,
            })
        }
        Err(e) => {
            // the spec itself cannot materialize this index: settle it
            // as a permanent failure instead of bouncing it forever
            let (epoch, slot, _) = grid(&ctx.spec, idx);
            let run_id = format!("{}-e{epoch}[{slot}]", ctx.spec.name);
            lock_ledger(&ctx.ledger).mark_failed(
                &run_id,
                epoch,
                slot,
                1,
                ErrorClass::Permanent.name(),
                &e.to_string(),
            )?;
            let mut g = lock(&ctx.shared);
            g.dispatching -= 1;
            g.stats.runs += 1;
            g.stats.failed += 1;
            Ok(Msg::Wait { ms: 10 })
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn settle_completion(
    ctx: &ConnCtx,
    key: &str,
    lease: u64,
    idx: u64,
    run_id: &str,
    attempts: u64,
    degraded: bool,
    csv: &str,
) -> Result<()> {
    // phase 1 — claim under the dispatch mutex: duplicate guard + the
    // `settling` marker that keeps the run covered during the I/O
    let walltime_s = {
        let mut g = lock(&ctx.shared);
        let released = g.leases.release(lease);
        if !g.begin_settlement(run_id) {
            g.fabric.completions_rejected += 1;
            drop(g);
            if telemetry::enabled() {
                telemetry::emit(EventKind::CompletionRejected {
                    run_id: run_id.to_string(),
                    worker: key.to_string(),
                });
            }
            return Ok(());
        }
        released.map(|l| l.granted.elapsed().as_secs_f64())
    };

    // phase 2 — durable work, dispatch mutex released: CSV lands fully
    // before the `completed` record, same crash discipline as the
    // local driver; both writes serialize on the ledger mutex alone
    let (epoch, slot, _) = grid(&ctx.spec, idx);
    let durable = publish_run_csv(&ctx.runs_dir, epoch, slot, csv).and_then(|()| {
        lock_ledger(&ctx.ledger).mark_completed(run_id, epoch, slot, attempts as u32, degraded)
    });

    // phase 3 — finalize under the dispatch mutex: the claim closes
    // whether or not the I/O succeeded (an I/O error is fatal for the
    // whole coordinator anyway)
    {
        let mut g = lock(&ctx.shared);
        g.settling.remove(run_id);
        durable?;
        g.completed.insert(run_id.to_string());
        // the reaper may have re-queued this idx while the worker was
        // silent; the accepted result settles it for good
        g.queue.retain(|&i| i != idx);
        g.stats.runs += 1;
        g.stats.completed += 1;
        g.stats.attempts += attempts;
        g.stats.retries += attempts.saturating_sub(1);
        if degraded {
            g.stats.degraded += 1;
        }
        g.fabric.completions_accepted += 1;
        if let Some(w) = walltime_s {
            g.walltimes_s.push(w);
        }
        g.accepted_this_session += 1;
        g.settle_check(ctx.cfg.stop_after_completions);
    }
    if telemetry::enabled() {
        telemetry::emit(EventKind::RunEnd {
            run_id: run_id.to_string(),
            ok: true,
            attempts,
            degraded,
        });
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn settle_failure(
    ctx: &ConnCtx,
    key: &str,
    lease: u64,
    idx: u64,
    run_id: &str,
    attempts: u64,
    class: &str,
    error: &str,
) -> Result<()> {
    // same three-phase protocol as settle_completion
    {
        let mut g = lock(&ctx.shared);
        g.leases.release(lease);
        if !g.begin_settlement(run_id) {
            g.fabric.completions_rejected += 1;
            drop(g);
            if telemetry::enabled() {
                telemetry::emit(EventKind::CompletionRejected {
                    run_id: run_id.to_string(),
                    worker: key.to_string(),
                });
            }
            return Ok(());
        }
    }

    let (epoch, slot, _) = grid(&ctx.spec, idx);
    let durable =
        lock_ledger(&ctx.ledger).mark_failed(run_id, epoch, slot, attempts as u32, class, error);

    {
        let mut g = lock(&ctx.shared);
        g.settling.remove(run_id);
        durable?;
        g.queue.retain(|&i| i != idx);
        g.stats.runs += 1;
        g.stats.failed += 1;
        g.stats.attempts += attempts;
        g.stats.retries += attempts.saturating_sub(1);
        g.fabric.remote_failures += 1;
    }
    if telemetry::enabled() {
        telemetry::emit(EventKind::RunEnd {
            run_id: run_id.to_string(),
            ok: false,
            attempts,
            degraded: false,
        });
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn shared() -> Shared {
        Shared {
            queue: VecDeque::new(),
            leases: LeaseTable::new(Duration::from_millis(100)),
            stats: RobustnessStats::default(),
            fabric: FabricStats::default(),
            walltimes_s: Vec::new(),
            accepted_this_session: 0,
            stopping: false,
            fatal: None,
            completed: HashSet::new(),
            settling: HashSet::new(),
            dispatching: 0,
        }
    }

    /// The duplicate guard must reject a second result for a run while
    /// the first settlement's ledger I/O is still in flight — the
    /// window the three-phase protocol opened by moving that I/O
    /// outside the dispatch mutex.
    #[test]
    fn settlement_claim_is_exclusive() {
        let mut g = shared();
        assert!(g.begin_settlement("demo-e0[0]"), "first claim wins");
        assert!(
            !g.begin_settlement("demo-e0[0]"),
            "concurrent duplicate must be rejected while the claim is open"
        );
        // finalize: claim closes, run becomes durably completed
        g.settling.remove("demo-e0[0]");
        g.completed.insert("demo-e0[0]".to_string());
        assert!(
            !g.begin_settlement("demo-e0[0]"),
            "zombie result after settlement must be rejected"
        );
        // an unrelated run is unaffected
        assert!(g.begin_settlement("demo-e0[1]"));
    }

    /// The accept loop's exit predicate must treat in-flight
    /// settlements and mid-dispatch pops as live work: with the ledger
    /// fsync outside the dispatch mutex, `queue.is_empty() &&
    /// leases.is_empty()` alone would declare the campaign settled
    /// while a result is mid-write (the PR 8 limbo race, reborn).
    #[test]
    fn open_claims_keep_the_session_unsettled() {
        let mut g = shared();
        assert!(g.settled_idle(), "empty state is settled");

        g.queue.push_back(3);
        assert!(!g.settled_idle(), "queued work");
        let idx = g.queue.pop_front().unwrap();
        g.dispatching += 1;
        assert!(!g.settled_idle(), "popped but not yet granted");
        g.dispatching -= 1;
        let lease = g.leases.grant(idx, "demo-e0[3]", "w#1", Instant::now());
        assert!(!g.settled_idle(), "leased work");

        g.leases.release(lease.id);
        assert!(g.begin_settlement("demo-e0[3]"));
        assert!(!g.settled_idle(), "claim open: ledger write in flight");
        g.settling.remove("demo-e0[3]");
        g.completed.insert("demo-e0[3]".to_string());
        assert!(g.settled_idle(), "claim closed: campaign settled");
    }

    /// The reaper must not re-queue an index whose run is mid
    /// settlement — the accepted result settles it for good.
    #[test]
    fn reaper_skips_runs_mid_settlement() {
        let mut g = shared();
        let lease = g.leases.grant(7, "demo-e1[3]", "w#1", Instant::now());
        // worker reports the result: lease released, claim opened
        g.leases.release(lease.id);
        assert!(g.begin_settlement("demo-e1[3]"));
        // the reaper's requeue predicate (mirrors the sweep in run())
        let requeue = !g.completed.contains("demo-e1[3]") && !g.settling.contains("demo-e1[3]");
        assert!(!requeue, "mid-settlement run must not be re-dispatched");
    }
}
