//! The Webots simulation loop: TraCI-coupled stepping with controllers.
//!
//! Per §2.5.3: Webots is the front-end; SUMO drives the traffic through
//! the SUMO Interface.  [`WebotsSim`] owns the world, connects a TraCI
//! client to the instance's SUMO back-end, steps it at `basicTimeStep`,
//! runs robot controllers at the interface's sampling period, and pushes
//! their actuation back through TraCI.

use crate::sumo::StepObs;
use crate::traci::TraciClient;
use crate::{Error, Result};

use super::controller::{controller_by_name, Controller, ControllerCmd, ControllerObs};
use super::nodes::{RobotNode, SumoInterface, WorldInfo};
use super::supervisor::{InstanceWatchdog, StopCondition, Supervisor};
use super::world::World;

/// A running Webots instance (front-end side).
pub struct WebotsSim {
    pub world_info: WorldInfo,
    pub sumo_interface: SumoInterface,
    traci: TraciClient,
    controllers: Vec<Box<dyn Controller>>,
    supervisor: Supervisor,
    /// Wall-clock limits ([`InstanceWatchdog`]); None = unguarded.
    watchdog: Option<InstanceWatchdog>,
    time_s: f32,
    steps: u64,
    controller_cmds: u64,
    /// Per-step observables as reported by the back-end.
    pub history: Vec<StepObs>,
}

/// Why the run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunEnd {
    /// Stop condition met — a completed run.
    Stopped,
    /// Step budget exhausted before the stop condition (the caller's
    /// walltime guard).
    BudgetExhausted,
}

impl WebotsSim {
    /// Open the world and connect to its SUMO back-end.  The TraCI port
    /// comes from the world's SumoInterface node — exactly the field the
    /// copy-propagation step rewrites per instance.
    pub fn open(world: &World) -> Result<WebotsSim> {
        let wi_node = world
            .find("WorldInfo")
            .ok_or_else(|| Error::World("world missing WorldInfo".into()))?;
        let world_info = WorldInfo::from_node(wi_node)?;
        let si_node = world
            .find("SumoInterface")
            .ok_or_else(|| Error::World("world missing SumoInterface".into()))?;
        let sumo_interface = SumoInterface::from_node(si_node)?;

        // connect() handshakes: a version-skewed back-end is refused
        // before any observable frame could be misparsed
        let traci = TraciClient::connect(sumo_interface.port)?;

        let mut controllers: Vec<Box<dyn Controller>> = Vec::new();
        for rn in world.find_all("Robot") {
            let robot = RobotNode::from_node(rn)?;
            controllers.push(controller_by_name(&robot.controller)?);
        }

        Ok(WebotsSim {
            world_info,
            sumo_interface,
            traci,
            controllers,
            supervisor: Supervisor::new(StopCondition::None),
            watchdog: None,
            time_s: 0.0,
            steps: 0,
            controller_cmds: 0,
            history: Vec::new(),
        })
    }

    pub fn with_stop_condition(mut self, c: StopCondition) -> Self {
        self.supervisor = Supervisor::new(c);
        self
    }

    /// Attach a wall-clock watchdog (walltime deadline + stall window),
    /// consulted around each burst of [`Self::run`].  The caller starts
    /// the watchdog's clock, so launch-time setup (duarouter, display
    /// acquisition) counts against the same deadline.
    pub fn with_watchdog(mut self, w: InstanceWatchdog) -> Self {
        self.watchdog = Some(w);
        self
    }

    pub fn time_s(&self) -> f32 {
        self.time_s
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn controller_cmds(&self) -> u64 {
        self.controller_cmds
    }

    /// One basicTimeStep: advance SUMO, then (at the sampling period)
    /// run controllers and actuate.
    pub fn step(&mut self) -> Result<StepObs> {
        let (n_active, mean_speed, flow, n_merged, n_exited) = self.traci.sim_step()?;
        let obs = StepObs {
            n_active,
            mean_speed,
            flow,
            n_merged,
            n_exited,
        };
        self.history.push(obs);
        self.time_s += self.world_info.basic_time_step_ms as f32 / 1000.0;
        self.steps += 1;

        let sample_every =
            (self.sumo_interface.sampling_period_ms / self.world_info.basic_time_step_ms).max(1);
        if self.steps % sample_every as u64 == 0 && !self.controllers.is_empty() {
            let state_rows = self.traci.get_state()?;
            let cobs = ControllerObs {
                time_s: self.time_s,
                state_rows,
            };
            let mut cmds: Vec<ControllerCmd> = Vec::new();
            for c in &mut self.controllers {
                cmds.extend(c.step(&cobs));
            }
            for cmd in cmds {
                match cmd {
                    ControllerCmd::SetSpeed { slot, speed } => {
                        self.traci.set_speed(slot, speed)?;
                        self.controller_cmds += 1;
                    }
                }
            }
        }
        Ok(obs)
    }

    /// `sample_every` basicTimeSteps per controller sampling period.
    fn sample_every(&self) -> u64 {
        (self.sumo_interface.sampling_period_ms / self.world_info.basic_time_step_ms).max(1) as u64
    }

    /// Advance `k` basicTimeSteps in ONE TraCI round trip (§Perf: the
    /// batched replacement for `k` × [`Self::step`]).  Controllers are
    /// NOT run inside the batch — callers batch at most up to the next
    /// sampling boundary (see [`Self::run`]).
    pub fn step_n(&mut self, k: u64) -> Result<Vec<StepObs>> {
        let obs = self.traci.sim_step_n(k as u32)?;
        let mut out = Vec::with_capacity(obs.len());
        for (n_active, mean_speed, flow, n_merged, n_exited) in obs {
            let o = StepObs {
                n_active,
                mean_speed,
                flow,
                n_merged,
                n_exited,
            };
            self.history.push(o);
            out.push(o);
        }
        self.time_s += k as f32 * self.world_info.basic_time_step_ms as f32 / 1000.0;
        self.steps += k;
        Ok(out)
    }

    /// Run controllers once against the current back-end state (the body
    /// of the sampling-period branch of [`Self::step`]).
    fn run_controllers(&mut self) -> Result<()> {
        if self.controllers.is_empty() {
            return Ok(());
        }
        let state_rows = self.traci.get_state()?;
        let cobs = ControllerObs {
            time_s: self.time_s,
            state_rows,
        };
        let mut cmds: Vec<ControllerCmd> = Vec::new();
        for c in &mut self.controllers {
            cmds.extend(c.step(&cobs));
        }
        for cmd in cmds {
            match cmd {
                ControllerCmd::SetSpeed { slot, speed } => {
                    self.traci.set_speed(slot, speed)?;
                    self.controller_cmds += 1;
                }
            }
        }
        Ok(())
    }

    /// Run until the stop condition fires or `max_steps` elapse.
    ///
    /// Steps are batched over TraCI between controller sampling points
    /// (`SimStepN`), cutting socket round trips by the sampling factor —
    /// semantics identical to a [`Self::step`] loop (verified by
    /// `batched_run_equals_stepwise` below).
    pub fn run(&mut self, max_steps: u64) -> Result<RunEnd> {
        let mut total_flow = 0.0f32;
        let sample_every = self.sample_every();
        let mut remaining = max_steps;
        while remaining > 0 {
            if let Some(w) = &self.watchdog {
                w.check_deadline()?;
            }
            // batch to the next sampling boundary
            let into_period = self.steps % sample_every;
            let k = (sample_every - into_period).min(remaining);
            let burst_start = self.watchdog.is_some().then(std::time::Instant::now);
            let burst = self.step_n(k)?;
            if let (Some(w), Some(t0)) = (&self.watchdog, burst_start) {
                w.check_burst(self.steps, t0.elapsed())?;
            }
            remaining -= k;
            let mut stopped = false;
            for o in &burst {
                total_flow += o.flow;
                let drained = o.n_active == 0.0 && self.time_s > 1.0;
                if self.supervisor.should_stop(self.time_s, drained, total_flow) {
                    stopped = true;
                }
            }
            if self.steps % sample_every == 0 {
                self.run_controllers()?;
            }
            if stopped {
                return Ok(RunEnd::Stopped);
            }
        }
        Ok(RunEnd::BudgetExhausted)
    }

    /// Back-end totals `(flow, merged, exited, spawned)` over this run
    /// so far.
    pub fn totals(&mut self) -> Result<(f32, f32, f32, u64)> {
        self.traci.get_totals()
    }

    /// Back-end `(steps, resident_steps)`: execution-path provenance
    /// for the dataset (how many steps rode the device-resident
    /// whole-run path vs the host chunk scheduler).
    pub fn run_stats(&mut self) -> Result<(u64, u64)> {
        self.traci.get_run_stats()
    }

    /// Full state snapshot from the back-end (supervisor access).
    pub fn state_snapshot(&mut self) -> Result<Vec<f32>> {
        self.traci.get_state()
    }

    /// Orderly shutdown of the back-end.
    pub fn close(mut self) -> Result<()> {
        self.traci.close()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::{duarouter, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
    use crate::traci::TraciServer;
    use crate::webots::nodes::sample_merge_world;
    use std::net::TcpListener;

    fn free_port() -> u16 {
        TcpListener::bind("127.0.0.1:0")
            .unwrap()
            .local_addr()
            .unwrap()
            .port()
    }

    fn backend(port: u16, horizon: f32, seed: u64) -> TraciServer {
        let scenario = MergeScenario::default();
        let net = scenario.network();
        let flows = FlowFile::merge_sample(1200.0, 300.0, horizon);
        let routes = duarouter(&net, &flows, seed).unwrap();
        let sim = SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()));
        TraciServer::spawn(port, sim).unwrap()
    }

    #[test]
    fn coupled_run_stops_on_sim_time() {
        let port = free_port();
        let server = backend(port, 60.0, 1);
        let world = sample_merge_world(port);
        // patch the world's port to the ephemeral test port
        let mut sim = WebotsSim::open(&world)
            .unwrap()
            .with_stop_condition(StopCondition::SimTime(30.0));
        let end = sim.run(10_000).unwrap();
        assert_eq!(end, RunEnd::Stopped);
        assert!((sim.time_s() - 30.0).abs() < 0.2);
        sim.close().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn controllers_actuate_over_traci() {
        let port = free_port();
        let server = backend(port, 120.0, 2);
        let world = sample_merge_world(port);
        let mut sim = WebotsSim::open(&world)
            .unwrap()
            .with_stop_condition(StopCondition::SimTime(60.0));
        sim.run(10_000).unwrap();
        assert!(
            sim.controller_cmds() > 0,
            "merge_assist must have issued SetSpeed commands"
        );
        sim.close().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn missing_backend_fails_to_open() {
        let world = sample_merge_world(free_port());
        assert!(WebotsSim::open(&world).is_err());
    }

    #[test]
    fn batched_run_equals_stepwise() {
        // same seed, same horizon: run() (SimStepN bursts) must produce
        // the identical observable history as a step() loop
        let run_history = {
            let port = free_port();
            let server = backend(port, 30.0, 7);
            let world = sample_merge_world(port);
            let mut sim = WebotsSim::open(&world)
                .unwrap()
                .with_stop_condition(StopCondition::SimTime(20.0));
            sim.run(10_000).unwrap();
            let h = sim.history.clone();
            sim.close().unwrap();
            server.join().unwrap();
            h
        };
        let step_history = {
            let port = free_port();
            let server = backend(port, 30.0, 7);
            let world = sample_merge_world(port);
            let mut sim = WebotsSim::open(&world).unwrap();
            for _ in 0..run_history.len() {
                sim.step().unwrap();
            }
            let h = sim.history.clone();
            sim.close().unwrap();
            server.join().unwrap();
            h
        };
        assert_eq!(run_history.len(), step_history.len());
        assert_eq!(run_history, step_history);
    }

    #[test]
    fn step_n_advances_time_and_history() {
        let port = free_port();
        let server = backend(port, 30.0, 8);
        let world = sample_merge_world(port);
        let mut sim = WebotsSim::open(&world).unwrap();
        let burst = sim.step_n(5).unwrap();
        assert_eq!(burst.len(), 5);
        assert_eq!(sim.steps(), 5);
        assert!((sim.time_s() - 0.5).abs() < 1e-5);
        sim.close().unwrap();
        server.join().unwrap();
    }

    #[test]
    fn history_accumulates() {
        let port = free_port();
        let server = backend(port, 30.0, 3);
        let world = sample_merge_world(port);
        let mut sim = WebotsSim::open(&world)
            .unwrap()
            .with_stop_condition(StopCondition::SimTime(10.0));
        sim.run(10_000).unwrap();
        assert_eq!(sim.history.len() as u64, sim.steps());
        assert!(sim.steps() >= 100);
        sim.close().unwrap();
        server.join().unwrap();
    }
}
