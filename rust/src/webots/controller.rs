//! Robot controllers.
//!
//! "Controllers are scripts ... that determine a node's functionality"
//! (§2.5.1).  Ours are rust trait objects resolved by name from the
//! world file's `controller "..."` field; the sample simulation's
//! `merge_assist` CAV controller implements a radar-based gap-management
//! policy for the on-ramp.

use crate::sumo::state::{ACTIVE, LANE, STATE_COLS, V, X};
use crate::{Error, Result};

use super::sensors::{radar_from_rows, RadarReading};

/// What a controller sees each sampling period.
#[derive(Debug, Clone)]
pub struct ControllerObs {
    pub time_s: f32,
    /// Full state snapshot (supervisor-grade access, like a Webots
    /// Supervisor controller).
    pub state_rows: Vec<f32>,
}

impl ControllerObs {
    pub fn num_slots(&self) -> usize {
        self.state_rows.len() / STATE_COLS
    }

    pub fn is_active(&self, slot: usize) -> bool {
        self.state_rows[slot * STATE_COLS + ACTIVE] > 0.5
    }

    pub fn x(&self, slot: usize) -> f32 {
        self.state_rows[slot * STATE_COLS + X]
    }

    pub fn v(&self, slot: usize) -> f32 {
        self.state_rows[slot * STATE_COLS + V]
    }

    pub fn lane(&self, slot: usize) -> f32 {
        self.state_rows[slot * STATE_COLS + LANE]
    }

    pub fn radar(&self, slot: usize, max_range: f32) -> RadarReading {
        radar_from_rows(&self.state_rows, slot, max_range)
    }
}

/// Actuation commands a controller may emit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ControllerCmd {
    /// Override a vehicle's speed (sent to SUMO via TraCI SetSpeed).
    SetSpeed { slot: u32, speed: f32 },
}

/// The controller interface: called every sampling period.
pub trait Controller: Send {
    fn name(&self) -> &str;
    fn step(&mut self, obs: &ControllerObs) -> Vec<ControllerCmd>;
}

/// The CAV merge-assist controller of the sample simulation.
///
/// Policy: find ramp-lane vehicles (lane 0); for each, use forward radar
/// to manage the approach — close up at `approach_speed` when the radar
/// is clear, back off proportionally to closing speed when a conflict
/// looms.  This is deliberately simple: the paper's point is the
/// *pipeline*, the controller just has to exercise sensors + TraCI
/// actuation end to end.
#[derive(Debug, Clone)]
pub struct MergeAssistController {
    pub radar_range: f32,
    pub approach_speed: f32,
    pub min_speed: f32,
    /// Gap [m] under which we start yielding.
    pub caution_gap: f32,
    commands_issued: u64,
}

impl Default for MergeAssistController {
    fn default() -> Self {
        MergeAssistController {
            radar_range: 150.0,
            approach_speed: 22.0,
            min_speed: 5.0,
            caution_gap: 30.0,
            commands_issued: 0,
        }
    }
}

impl MergeAssistController {
    pub fn commands_issued(&self) -> u64 {
        self.commands_issued
    }
}

impl Controller for MergeAssistController {
    fn name(&self) -> &str {
        "merge_assist"
    }

    fn step(&mut self, obs: &ControllerObs) -> Vec<ControllerCmd> {
        let mut cmds = Vec::new();
        for slot in 0..obs.num_slots() {
            if !obs.is_active(slot) || obs.lane(slot) != 0.0 {
                continue;
            }
            let r = obs.radar(slot, self.radar_range);
            let target = if r.distance >= self.caution_gap {
                self.approach_speed
            } else {
                // yield proportionally to how compressed the gap is
                let f = (r.distance / self.caution_gap).clamp(0.0, 1.0);
                (self.approach_speed * f).max(self.min_speed)
            };
            if (target - obs.v(slot)).abs() > 0.5 {
                cmds.push(ControllerCmd::SetSpeed {
                    slot: slot as u32,
                    speed: target,
                });
            }
        }
        self.commands_issued += cmds.len() as u64;
        cmds
    }
}

/// A CACC platooning controller — the second workload class the paper's
/// related work motivates (Karoui et al., "Performance Evaluation of
/// Vehicular Platoons using Webots" [13]).  Vehicles on the platoon lane
/// hold a constant distance-gap to their predecessor using the forward
/// radar: classic cooperative adaptive cruise control
///   v_cmd = v_ego + k_gap·(gap − target) − k_closing·closing_speed.
/// The leader (clear radar) cruises at `cruise_speed`.
#[derive(Debug, Clone)]
pub struct PlatoonController {
    pub platoon_lane: f32,
    pub radar_range: f32,
    pub cruise_speed: f32,
    pub target_gap: f32,
    pub k_gap: f32,
    pub k_closing: f32,
    commands_issued: u64,
}

impl Default for PlatoonController {
    fn default() -> Self {
        PlatoonController {
            platoon_lane: 1.0,
            radar_range: 150.0,
            cruise_speed: 25.0,
            target_gap: 12.0,
            k_gap: 0.4,
            k_closing: 0.8,
            commands_issued: 0,
        }
    }
}

impl PlatoonController {
    pub fn commands_issued(&self) -> u64 {
        self.commands_issued
    }
}

impl Controller for PlatoonController {
    fn name(&self) -> &str {
        "platoon"
    }

    fn step(&mut self, obs: &ControllerObs) -> Vec<ControllerCmd> {
        let mut cmds = Vec::new();
        for slot in 0..obs.num_slots() {
            if !obs.is_active(slot) || obs.lane(slot) != self.platoon_lane {
                continue;
            }
            let r = obs.radar(slot, self.radar_range);
            let target = if r.distance >= self.radar_range - 1e-3 {
                // platoon leader: cruise
                self.cruise_speed
            } else {
                let v = obs.v(slot);
                (v + self.k_gap * (r.distance - self.target_gap)
                    - self.k_closing * r.closing_speed)
                    .clamp(0.0, self.cruise_speed * 1.2)
            };
            if (target - obs.v(slot)).abs() > 0.25 {
                cmds.push(ControllerCmd::SetSpeed {
                    slot: slot as u32,
                    speed: target,
                });
            }
        }
        self.commands_issued += cmds.len() as u64;
        cmds
    }
}

/// A controller that does nothing (`controller "void"` in Webots).
#[derive(Debug, Default, Clone)]
pub struct VoidController;

impl Controller for VoidController {
    fn name(&self) -> &str {
        "void"
    }

    fn step(&mut self, _obs: &ControllerObs) -> Vec<ControllerCmd> {
        Vec::new()
    }
}

/// Resolve a controller by its world-file name.
pub fn controller_by_name(name: &str) -> Result<Box<dyn Controller>> {
    match name {
        "merge_assist" => Ok(Box::new(MergeAssistController::default())),
        "platoon" => Ok(Box::new(PlatoonController::default())),
        "void" => Ok(Box::new(VoidController)),
        other => Err(Error::World(format!("unknown controller '{other}'"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(items: &[(f32, f32, f32, f32)]) -> ControllerObs {
        ControllerObs {
            time_s: 0.0,
            state_rows: items.iter().flat_map(|&(x, v, l, a)| [x, v, l, a]).collect(),
        }
    }

    #[test]
    fn clear_radar_commands_approach_speed() {
        let mut c = MergeAssistController::default();
        let cmds = c.step(&obs(&[(100.0, 10.0, 0.0, 1.0)]));
        assert_eq!(
            cmds,
            vec![ControllerCmd::SetSpeed { slot: 0, speed: 22.0 }]
        );
    }

    #[test]
    fn close_target_commands_yield() {
        let mut c = MergeAssistController::default();
        // target 15 m ahead → half of caution_gap → half approach speed
        let cmds = c.step(&obs(&[(100.0, 20.0, 0.0, 1.0), (115.0, 5.0, 0.0, 1.0)]));
        match cmds[0] {
            ControllerCmd::SetSpeed { slot: 0, speed } => {
                assert!((speed - 11.0).abs() < 0.5, "speed {speed}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn mainline_vehicles_untouched() {
        let mut c = MergeAssistController::default();
        assert!(c.step(&obs(&[(100.0, 10.0, 1.0, 1.0)])).is_empty());
    }

    #[test]
    fn no_command_when_already_at_target() {
        let mut c = MergeAssistController::default();
        assert!(c.step(&obs(&[(100.0, 22.0, 0.0, 1.0)])).is_empty());
    }

    #[test]
    fn registry_resolves() {
        assert!(controller_by_name("merge_assist").is_ok());
        assert!(controller_by_name("platoon").is_ok());
        assert!(controller_by_name("void").is_ok());
        assert!(controller_by_name("skynet").is_err());
    }

    #[test]
    fn platoon_leader_cruises() {
        let mut c = PlatoonController::default();
        let cmds = c.step(&obs(&[(100.0, 10.0, 1.0, 1.0)]));
        assert_eq!(
            cmds,
            vec![ControllerCmd::SetSpeed { slot: 0, speed: 25.0 }]
        );
    }

    #[test]
    fn platoon_follower_regulates_gap() {
        let mut c = PlatoonController::default();
        // follower 20 m behind a same-speed leader: gap > target → close up
        let cmds = c.step(&obs(&[(100.0, 20.0, 1.0, 1.0), (120.0, 20.0, 1.0, 1.0)]));
        let follower_cmd = cmds
            .iter()
            .find(|c| matches!(c, ControllerCmd::SetSpeed { slot: 0, .. }))
            .expect("follower commanded");
        match follower_cmd {
            ControllerCmd::SetSpeed { speed, .. } => {
                assert!(*speed > 20.0, "closes a too-wide gap: {speed}");
            }
        }
        // too-tight gap → back off
        let cmds = c.step(&obs(&[(100.0, 20.0, 1.0, 1.0), (105.0, 20.0, 1.0, 1.0)]));
        match cmds
            .iter()
            .find(|c| matches!(c, ControllerCmd::SetSpeed { slot: 0, .. }))
            .expect("follower commanded")
        {
            ControllerCmd::SetSpeed { speed, .. } => {
                assert!(*speed < 20.0, "opens a too-tight gap: {speed}");
            }
        }
    }

    #[test]
    fn platoon_ignores_other_lanes() {
        let mut c = PlatoonController::default();
        assert!(c.step(&obs(&[(100.0, 10.0, 2.0, 1.0)])).is_empty());
    }
}
