//! Stop conditions and run supervision.
//!
//! "When starting a simulation in headless mode ... users must build in a
//! stop condition for their simulation, or else the Webots instance will
//! run indefinitely" (§3.1.3).  [`StopCondition`] is that build-in; the
//! [`Supervisor`] evaluates it each step.

/// When to end a batch simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopCondition {
    /// Stop after this much simulated time [s].
    SimTime(f32),
    /// Stop once every scheduled vehicle has been inserted and retired.
    Drained,
    /// Stop when `count` vehicles have crossed the road end.
    FlowCount(u32),
    /// No stop condition: the §3.1.3 footgun, runs until walltime kill.
    None,
}

/// Evaluates the stop condition against live simulation signals.
#[derive(Debug, Clone, Copy)]
pub struct Supervisor {
    pub condition: StopCondition,
}

impl Supervisor {
    pub fn new(condition: StopCondition) -> Self {
        Supervisor { condition }
    }

    /// Should the run stop now?
    pub fn should_stop(&self, time_s: f32, drained: bool, total_flow: f32) -> bool {
        match self.condition {
            StopCondition::SimTime(t) => time_s >= t,
            StopCondition::Drained => drained,
            StopCondition::FlowCount(n) => total_flow >= n as f32,
            StopCondition::None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_time_stop() {
        let s = Supervisor::new(StopCondition::SimTime(300.0));
        assert!(!s.should_stop(299.9, false, 0.0));
        assert!(s.should_stop(300.0, false, 0.0));
    }

    #[test]
    fn drained_stop() {
        let s = Supervisor::new(StopCondition::Drained);
        assert!(!s.should_stop(10.0, false, 0.0));
        assert!(s.should_stop(10.0, true, 0.0));
    }

    #[test]
    fn flow_count_stop() {
        let s = Supervisor::new(StopCondition::FlowCount(10));
        assert!(!s.should_stop(0.0, false, 9.0));
        assert!(s.should_stop(0.0, false, 10.0));
    }

    #[test]
    fn none_never_stops() {
        let s = Supervisor::new(StopCondition::None);
        assert!(!s.should_stop(1e9, true, 1e9));
    }
}
