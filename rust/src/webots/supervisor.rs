//! Stop conditions and run supervision.
//!
//! "When starting a simulation in headless mode ... users must build in a
//! stop condition for their simulation, or else the Webots instance will
//! run indefinitely" (§3.1.3).  [`StopCondition`] is that build-in; the
//! [`Supervisor`] evaluates it each step.
//!
//! [`InstanceWatchdog`] is the wall-clock counterpart: a per-instance
//! walltime deadline plus a stall window, checked around each TraCI
//! burst of [`super::WebotsSim::run`] so a wedged back-end kills ONE
//! run instead of eating the node's whole PBS walltime.

use std::time::{Duration, Instant};

use crate::telemetry::{self, EventKind};
use crate::{Error, Result};

/// When to end a batch simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopCondition {
    /// Stop after this much simulated time [s].
    SimTime(f32),
    /// Stop once every scheduled vehicle has been inserted and retired.
    Drained,
    /// Stop when `count` vehicles have crossed the road end.
    FlowCount(u32),
    /// No stop condition: the §3.1.3 footgun, runs until walltime kill.
    None,
}

/// Evaluates the stop condition against live simulation signals.
#[derive(Debug, Clone, Copy)]
pub struct Supervisor {
    pub condition: StopCondition,
}

impl Supervisor {
    pub fn new(condition: StopCondition) -> Self {
        Supervisor { condition }
    }

    /// Should the run stop now?
    pub fn should_stop(&self, time_s: f32, drained: bool, total_flow: f32) -> bool {
        match self.condition {
            StopCondition::SimTime(t) => time_s >= t,
            StopCondition::Drained => drained,
            StopCondition::FlowCount(n) => total_flow >= n as f32,
            StopCondition::None => false,
        }
    }
}

/// Wall-clock limits for one instance (both disabled by default: the
/// step budget of [`super::WebotsSim::run`] stays the only guard).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WatchdogSpec {
    /// Hard deadline for the whole instance (route generation through
    /// shutdown); exceeding it yields [`Error::WalltimeExceeded`].
    pub walltime: Option<Duration>,
    /// Max wall time ONE TraCI burst may take.  A healthy burst is a
    /// handful of milliseconds of physics; a burst that blows this
    /// window means the back-end stalled mid-run
    /// ([`Error::Stalled`]).
    pub stall_window: Option<Duration>,
}

/// Self-checking watchdog: created when the instance launches, consulted
/// around every burst.  No monitor thread — the checks ride the run loop
/// itself, so an in-process stall is detected as soon as the burst
/// returns (a worker that never returns at all is the coordinator
/// fabric's to kill; see ROADMAP).
#[derive(Debug)]
pub struct InstanceWatchdog {
    label: String,
    spec: WatchdogSpec,
    started: Instant,
}

impl InstanceWatchdog {
    /// Start the clock.  `label` names the run in the
    /// [`Error::WalltimeExceeded`] payload.
    pub fn new(label: impl Into<String>, spec: WatchdogSpec) -> Self {
        InstanceWatchdog {
            label: label.into(),
            spec,
            started: Instant::now(),
        }
    }

    /// Walltime deadline — checked before each burst (and usable right
    /// after launch-time setup phases like duarouter).
    pub fn check_deadline(&self) -> Result<()> {
        if let Some(limit) = self.spec.walltime {
            if self.started.elapsed() > limit {
                if telemetry::enabled() {
                    telemetry::emit(EventKind::WatchdogFire {
                        run_id: self.label.clone(),
                        kind: "walltime".to_string(),
                        detail: format!("elapsed {:?} > limit {limit:?}", self.started.elapsed()),
                    });
                }
                return Err(Error::WalltimeExceeded(self.label.clone()));
            }
        }
        Ok(())
    }

    /// Stall window — checked after each burst with the burst's wall
    /// time and the cumulative step count (the [`Error::Stalled`]
    /// payload).
    pub fn check_burst(&self, steps: u64, burst_elapsed: Duration) -> Result<()> {
        if let Some(window) = self.spec.stall_window {
            if burst_elapsed > window {
                if telemetry::enabled() {
                    telemetry::emit(EventKind::WatchdogFire {
                        run_id: self.label.clone(),
                        kind: "stall".to_string(),
                        detail: format!(
                            "burst {burst_elapsed:?} > window {window:?} after {steps} steps"
                        ),
                    });
                }
                return Err(Error::Stalled(steps));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_time_stop() {
        let s = Supervisor::new(StopCondition::SimTime(300.0));
        assert!(!s.should_stop(299.9, false, 0.0));
        assert!(s.should_stop(300.0, false, 0.0));
    }

    #[test]
    fn drained_stop() {
        let s = Supervisor::new(StopCondition::Drained);
        assert!(!s.should_stop(10.0, false, 0.0));
        assert!(s.should_stop(10.0, true, 0.0));
    }

    #[test]
    fn flow_count_stop() {
        let s = Supervisor::new(StopCondition::FlowCount(10));
        assert!(!s.should_stop(0.0, false, 9.0));
        assert!(s.should_stop(0.0, false, 10.0));
    }

    #[test]
    fn none_never_stops() {
        let s = Supervisor::new(StopCondition::None);
        assert!(!s.should_stop(1e9, true, 1e9));
    }

    #[test]
    fn default_watchdog_is_inert() {
        let w = InstanceWatchdog::new("r", WatchdogSpec::default());
        assert!(w.check_deadline().is_ok());
        assert!(w.check_burst(1_000_000, Duration::from_secs(3600)).is_ok());
    }

    #[test]
    fn walltime_deadline_fires() {
        let w = InstanceWatchdog::new(
            "run-x",
            WatchdogSpec {
                walltime: Some(Duration::ZERO),
                stall_window: None,
            },
        );
        std::thread::sleep(Duration::from_millis(2));
        match w.check_deadline() {
            Err(Error::WalltimeExceeded(label)) => assert_eq!(label, "run-x"),
            other => panic!("expected walltime kill, got {other:?}"),
        }
    }

    #[test]
    fn stall_window_fires_on_slow_burst() {
        let w = InstanceWatchdog::new(
            "r",
            WatchdogSpec {
                walltime: None,
                stall_window: Some(Duration::from_millis(50)),
            },
        );
        assert!(w.check_burst(10, Duration::from_millis(5)).is_ok());
        match w.check_burst(42, Duration::from_millis(120)) {
            Err(Error::Stalled(steps)) => assert_eq!(steps, 42),
            other => panic!("expected stall kill, got {other:?}"),
        }
    }
}
