//! The Webots substrate: worlds, robots, controllers, sensors, stepping.
//!
//! Webots is the *front-end* of the paper's simulation pair — it owns the
//! scene tree, the robot controllers and the sensor suite, while SUMO
//! puppeteers the traffic through the SUMO Interface node (§2.5.3).  We
//! implement the pieces the pipeline exercises:
//!
//! * [`world`] — `.wbt` world files: a human-readable tree format the
//!   pipeline's copy-propagation rewrites (the paper edits the SUMO
//!   Interface port in each copy with a text editor, §3.1.5),
//! * [`nodes`] — typed views of the standard nodes (WorldInfo with the
//!   'Optimal Thread Count' knob, SumoInterface with the port and
//!   sampling period, Robot, sensors),
//! * [`controller`] — the controller interface and the CAV merge-assist
//!   controller of the sample simulation,
//! * [`sensors`] — radar/GPS/distance readings derived from the traffic
//!   state (mirroring the AOT radar kernel),
//! * [`physics`] — the simulation loop: drives the SUMO back-end over
//!   TraCI, runs controllers at their sampling period, actuates,
//! * [`mode`] — GUI vs headless, realtime vs fast,
//! * [`supervisor`] — stop conditions ("users must build in a stop
//!   condition ... or else the Webots instance will run indefinitely",
//!   §3.1.3).

pub mod controller;
pub mod mode;
pub mod nodes;
pub mod physics;
pub mod sensors;
pub mod supervisor;
pub mod world;

pub use controller::{Controller, ControllerCmd, ControllerObs, MergeAssistController};
pub use mode::{RunSpeed, SimMode};
pub use nodes::{RobotNode, SensorSpec, SumoInterface, WorldInfo};
pub use physics::WebotsSim;
pub use supervisor::{InstanceWatchdog, StopCondition, Supervisor, WatchdogSpec};
pub use world::{Node, World};
