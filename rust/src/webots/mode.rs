//! Simulation modes: GUI vs headless, realtime vs fast.
//!
//! The pipeline's four functionalities (§3.1) are combinations of these:
//! GUI over SSH-X11, headless under Xvfb, one-off or batched.  The
//! paper's batch command runs `webots --batch --mode=realtime` under
//! `xvfb-run -a`.

use crate::display::{DisplayHandle, DisplayRegistry, XvfbRun};
use crate::Result;

/// Where renderings go.
#[derive(Debug)]
pub enum SimMode {
    /// GUI streamed over a forwarded X11 display (`ssh -X`).
    Gui { display: DisplayHandle },
    /// Headless under an Xvfb framebuffer.
    Headless { display: DisplayHandle },
}

impl SimMode {
    /// Acquire a headless framebuffer the way the pipeline does:
    /// `xvfb-run`, with or without `-a`.
    pub fn headless(registry: &DisplayRegistry, auto_probe: bool) -> Result<SimMode> {
        let xvfb = if auto_probe {
            XvfbRun::auto()
        } else {
            XvfbRun::default()
        };
        Ok(SimMode::Headless {
            display: xvfb.acquire(registry)?,
        })
    }

    pub fn display_number(&self) -> u32 {
        match self {
            SimMode::Gui { display } | SimMode::Headless { display } => display.number,
        }
    }

    pub fn is_headless(&self) -> bool {
        matches!(self, SimMode::Headless { .. })
    }
}

/// Pacing: `--mode=realtime` paces to the wall clock; `fast` runs as
/// fast as the hardware allows.  On the virtual clock, realtime maps
/// virtual DT to wall DT when demanded (demo/GUI), fast never sleeps.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RunSpeed {
    Realtime,
    #[default]
    Fast,
}

impl RunSpeed {
    pub fn parse(s: &str) -> Option<RunSpeed> {
        match s {
            "realtime" => Some(RunSpeed::Realtime),
            "fast" => Some(RunSpeed::Fast),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headless_acquires_display() {
        let reg = DisplayRegistry::new();
        let m = SimMode::headless(&reg, true).unwrap();
        assert!(m.is_headless());
        assert_eq!(m.display_number(), 99);
    }

    #[test]
    fn parallel_headless_needs_auto_probe() {
        let reg = DisplayRegistry::new();
        let _m1 = SimMode::headless(&reg, false).unwrap();
        assert!(SimMode::headless(&reg, false).is_err());
        let m3 = SimMode::headless(&reg, true).unwrap();
        assert_eq!(m3.display_number(), 100);
    }

    #[test]
    fn run_speed_parse() {
        assert_eq!(RunSpeed::parse("realtime"), Some(RunSpeed::Realtime));
        assert_eq!(RunSpeed::parse("fast"), Some(RunSpeed::Fast));
        assert_eq!(RunSpeed::parse("warp9"), None);
    }
}
