//! Sensor models: readings derived from the traffic state.
//!
//! The radar math mirrors `python/compile/kernels/radar.py` exactly (the
//! AOT path computes the same quantity inside the fused step; this native
//! version serves controllers when the state arrives over TraCI).

use crate::sumo::state::{Traffic, ACTIVE, LANE, STATE_COLS, V, X};

/// A forward-radar return.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RadarReading {
    /// Distance to nearest target ahead (== max_range when clear).
    pub distance: f32,
    /// Ego speed minus target speed (0 when clear).
    pub closing_speed: f32,
}

/// GPS fix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpsReading {
    pub x: f32,
    pub lane: f32,
    pub speed: f32,
}

/// Forward radar over a raw state snapshot (flat rows, as delivered by
/// TraCI `GetState`). Mirrors `radar_ref`.
pub fn radar_from_rows(rows: &[f32], ego: usize, max_range: f32) -> RadarReading {
    let n = rows.len() / STATE_COLS;
    let at = |i: usize, c: usize| rows[i * STATE_COLS + c];
    if at(ego, ACTIVE) < 0.5 {
        return RadarReading {
            distance: max_range,
            closing_speed: 0.0,
        };
    }
    let xi = at(ego, X);
    let mut rng = max_range;
    for j in 0..n {
        if at(j, ACTIVE) < 0.5 {
            continue;
        }
        let dx = at(j, X) - xi;
        if dx > 1e-6 && dx <= max_range && dx < rng {
            rng = dx;
        }
    }
    if rng >= max_range - 1e-6 {
        return RadarReading {
            distance: max_range,
            closing_speed: 0.0,
        };
    }
    // mask-min tie-break on target speed, mirroring the kernel
    let mut tv = f32::INFINITY;
    for j in 0..n {
        if at(j, ACTIVE) < 0.5 {
            continue;
        }
        let dx = at(j, X) - xi;
        if dx > 1e-6 && dx <= rng {
            tv = tv.min(at(j, V));
        }
    }
    RadarReading {
        distance: rng,
        closing_speed: at(ego, V) - tv,
    }
}

/// GPS over a snapshot.
pub fn gps_from_rows(rows: &[f32], ego: usize) -> GpsReading {
    GpsReading {
        x: rows[ego * STATE_COLS + X],
        lane: rows[ego * STATE_COLS + LANE],
        speed: rows[ego * STATE_COLS + V],
    }
}

/// Convenience over a [`Traffic`] (native path).
pub fn radar(t: &Traffic, ego: usize, max_range: f32) -> RadarReading {
    radar_from_rows(&t.state, ego, max_range)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sumo::state::DriverParams;

    fn rows(items: &[(f32, f32, f32, f32)]) -> Vec<f32> {
        items.iter().flat_map(|&(x, v, l, a)| [x, v, l, a]).collect()
    }

    #[test]
    fn radar_sees_nearest_any_lane() {
        let r = rows(&[
            (100.0, 30.0, 1.0, 1.0),
            (140.0, 10.0, 2.0, 1.0),
            (160.0, 5.0, 1.0, 1.0),
        ]);
        let hit = radar_from_rows(&r, 0, 150.0);
        assert_eq!(hit.distance, 40.0);
        assert_eq!(hit.closing_speed, 20.0);
    }

    #[test]
    fn radar_clear_when_out_of_range() {
        let r = rows(&[(0.0, 30.0, 1.0, 1.0), (500.0, 0.0, 1.0, 1.0)]);
        let hit = radar_from_rows(&r, 0, 150.0);
        assert_eq!(hit.distance, 150.0);
        assert_eq!(hit.closing_speed, 0.0);
    }

    #[test]
    fn radar_ignores_inactive() {
        let r = rows(&[(0.0, 30.0, 1.0, 1.0), (50.0, 0.0, 1.0, 0.0)]);
        assert_eq!(radar_from_rows(&r, 0, 150.0).distance, 150.0);
    }

    #[test]
    fn radar_matches_native_traffic_path() {
        let mut t = Traffic::new(3);
        t.spawn(100.0, 30.0, 1.0, DriverParams::default());
        t.spawn(140.0, 10.0, 2.0, DriverParams::default());
        t.spawn(160.0, 5.0, 1.0, DriverParams::default());
        assert_eq!(radar(&t, 0, 150.0), radar_from_rows(&t.state, 0, 150.0));
    }

    #[test]
    fn gps_reads_position() {
        let r = rows(&[(123.0, 17.0, 2.0, 1.0)]);
        let g = gps_from_rows(&r, 0);
        assert_eq!((g.x, g.lane, g.speed), (123.0, 2.0, 17.0));
    }
}
