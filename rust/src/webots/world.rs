//! `.wbt` world files: parse, query, edit, render.
//!
//! Webots worlds are "human-readable with any of your favorite text
//! editors, so a script could easily be created to propagate n copies of
//! the simulation and then update them to have unique values for the
//! SUMO Interface port" (§3.1.5) — that script is
//! `pipeline::copies`, and this module is its editor.
//!
//! Grammar (a faithful subset of VRML/wbt):
//!
//! ```text
//! #VRML_SIM R2021a utf8
//! NodeType {
//!   fieldName value tokens ...
//!   ChildNodeType {
//!     ...
//!   }
//! }
//! ```

use crate::{Error, Result};

/// A node in the scene tree.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub node_type: String,
    /// Scalar fields in declaration order.
    pub fields: Vec<(String, String)>,
    pub children: Vec<Node>,
}

impl Node {
    pub fn new(node_type: impl Into<String>) -> Self {
        Node {
            node_type: node_type.into(),
            fields: Vec::new(),
            children: Vec::new(),
        }
    }

    pub fn with_field(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.fields.push((k.into(), v.into()));
        self
    }

    pub fn with_child(mut self, c: Node) -> Self {
        self.children.push(c);
        self
    }

    pub fn field(&self, name: &str) -> Option<&str> {
        self.fields
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn field_f32(&self, name: &str) -> Option<f32> {
        self.field(name)?.parse().ok()
    }

    pub fn field_u32(&self, name: &str) -> Option<u32> {
        self.field(name)?.parse().ok()
    }

    pub fn set_field(&mut self, name: &str, value: impl Into<String>) {
        let value = value.into();
        for (k, v) in &mut self.fields {
            if k == name {
                *v = value;
                return;
            }
        }
        self.fields.push((name.to_string(), value));
    }
}

/// A parsed world: header + top-level nodes ("Robot nodes should always
/// be under the root node", §2.5.1 — top level IS the root's child list).
#[derive(Debug, Clone, PartialEq)]
pub struct World {
    pub header: String,
    pub nodes: Vec<Node>,
}

impl World {
    pub const HEADER: &'static str = "#VRML_SIM R2021a utf8";

    pub fn new() -> Self {
        World {
            header: Self::HEADER.to_string(),
            nodes: Vec::new(),
        }
    }

    /// First node of a given type anywhere in the tree (depth-first).
    pub fn find(&self, node_type: &str) -> Option<&Node> {
        fn walk<'a>(nodes: &'a [Node], t: &str) -> Option<&'a Node> {
            for n in nodes {
                if n.node_type == t {
                    return Some(n);
                }
                if let Some(hit) = walk(&n.children, t) {
                    return Some(hit);
                }
            }
            None
        }
        walk(&self.nodes, node_type)
    }

    pub fn find_mut(&mut self, node_type: &str) -> Option<&mut Node> {
        fn walk<'a>(nodes: &'a mut [Node], t: &str) -> Option<&'a mut Node> {
            for n in nodes {
                if n.node_type == t {
                    return Some(n);
                }
                if let Some(hit) = walk(&mut n.children, t) {
                    return Some(hit);
                }
            }
            None
        }
        walk(&mut self.nodes, node_type)
    }

    /// All nodes of a type (e.g. every `Robot`).
    pub fn find_all(&self, node_type: &str) -> Vec<&Node> {
        let mut out = Vec::new();
        fn walk<'a>(nodes: &'a [Node], t: &str, out: &mut Vec<&'a Node>) {
            for n in nodes {
                if n.node_type == t {
                    out.push(n);
                }
                walk(&n.children, t, out);
            }
        }
        walk(&self.nodes, node_type, &mut out);
        out
    }

    /// Parse `.wbt` text.
    pub fn parse(text: &str) -> Result<World> {
        let mut lines = text.lines();
        let header = match lines.next() {
            Some(l) if l.starts_with("#VRML_SIM") => l.to_string(),
            _ => return Err(Error::World("missing #VRML_SIM header".into())),
        };
        let mut tokens: Vec<String> = Vec::new();
        for line in lines {
            let line = line.split('#').next().unwrap_or("");
            for tok in line.split_whitespace() {
                tokens.push(tok.to_string());
            }
        }
        let mut pos = 0usize;
        let mut nodes = Vec::new();
        while pos < tokens.len() {
            let (node, next) = parse_node(&tokens, pos)?;
            nodes.push(node);
            pos = next;
        }
        Ok(World { header, nodes })
    }

    /// Render back to `.wbt` text. `parse(render(w)) == w`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header);
        out.push('\n');
        for n in &self.nodes {
            render_node(n, 0, &mut out);
        }
        out
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.render())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<World> {
        World::parse(&std::fs::read_to_string(path)?)
    }
}

impl Default for World {
    fn default() -> Self {
        Self::new()
    }
}

/// Recursive-descent node parse: `Type { field... child... }`.
fn parse_node(tokens: &[String], mut pos: usize) -> Result<(Node, usize)> {
    let node_type = tokens
        .get(pos)
        .ok_or_else(|| Error::World("expected node type".into()))?
        .clone();
    if !node_type
        .chars()
        .next()
        .map(|c| c.is_ascii_uppercase())
        .unwrap_or(false)
    {
        return Err(Error::World(format!(
            "node type must be capitalized: '{node_type}'"
        )));
    }
    pos += 1;
    if tokens.get(pos).map(String::as_str) != Some("{") {
        return Err(Error::World(format!("expected '{{' after {node_type}")));
    }
    pos += 1;

    let mut node = Node::new(node_type);
    while pos < tokens.len() {
        let tok = &tokens[pos];
        if tok == "}" {
            return Ok((node, pos + 1));
        }
        let is_child = tok
            .chars()
            .next()
            .map(|c| c.is_ascii_uppercase())
            .unwrap_or(false)
            && tokens.get(pos + 1).map(String::as_str) == Some("{");
        if is_child {
            let (child, next) = parse_node(tokens, pos)?;
            node.children.push(child);
            pos = next;
        } else {
            // field: name + value tokens until the next field name,
            // child, or '}'. Values: quoted strings stay one token per
            // whitespace-split word; rejoin them.
            let name = tok.clone();
            pos += 1;
            let mut value_parts: Vec<String> = Vec::new();
            while pos < tokens.len() {
                let t = &tokens[pos];
                if t == "}" {
                    break;
                }
                let next_is_open = tokens.get(pos + 1).map(String::as_str) == Some("{");
                let starts_upper = t
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_uppercase())
                    .unwrap_or(false);
                if starts_upper && next_is_open {
                    break;
                }
                // lowercase bare token after at least one value token ⇒
                // next field name
                let starts_lower = t
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_lowercase())
                    .unwrap_or(false);
                if !value_parts.is_empty() && starts_lower && !t.starts_with('"') {
                    // heuristic: numbers/quoted continue a value; a bare
                    // identifier starts the next field
                    if t.parse::<f64>().is_err() && *t != "TRUE" && *t != "FALSE" {
                        break;
                    }
                }
                value_parts.push(t.clone());
                pos += 1;
            }
            if value_parts.is_empty() {
                return Err(Error::World(format!("field '{name}' has no value")));
            }
            node.fields.push((name, value_parts.join(" ")));
        }
    }
    Err(Error::World(format!(
        "unterminated node '{}'",
        node.node_type
    )))
}

fn render_node(n: &Node, depth: usize, out: &mut String) {
    let pad = "  ".repeat(depth);
    out.push_str(&format!("{pad}{} {{\n", n.node_type));
    for (k, v) in &n.fields {
        out.push_str(&format!("{pad}  {k} {v}\n"));
    }
    for c in &n.children {
        render_node(c, depth + 1, out);
    }
    out.push_str(&format!("{pad}}}\n"));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::webots::nodes::sample_merge_world;

    #[test]
    fn parse_render_roundtrip() {
        let w = sample_merge_world(8873);
        let text = w.render();
        let back = World::parse(&text).unwrap();
        assert_eq!(w, back);
    }

    #[test]
    fn find_nested_nodes() {
        let w = sample_merge_world(8873);
        assert!(w.find("WorldInfo").is_some());
        assert!(w.find("SumoInterface").is_some());
        assert!(w.find("Radar").is_some(), "radar nested under Robot");
        assert!(w.find("FluxCapacitor").is_none());
    }

    #[test]
    fn set_field_edits_port() {
        let mut w = sample_merge_world(8873);
        w.find_mut("SumoInterface")
            .unwrap()
            .set_field("port", "8880");
        assert_eq!(w.find("SumoInterface").unwrap().field_u32("port"), Some(8880));
    }

    #[test]
    fn parse_rejects_headerless() {
        assert!(World::parse("WorldInfo { }").is_err());
    }

    #[test]
    fn parse_rejects_unterminated() {
        let t = "#VRML_SIM R2021a utf8\nWorldInfo {\n  basicTimeStep 100\n";
        assert!(World::parse(t).is_err());
    }

    #[test]
    fn quoted_string_fields_survive() {
        let t = "#VRML_SIM R2021a utf8\nRobot {\n  name \"cav 0\"\n  controller \"merge_assist\"\n}\n";
        let w = World::parse(t).unwrap();
        let r = w.find("Robot").unwrap();
        assert_eq!(r.field("name"), Some("\"cav 0\""));
        assert_eq!(r.field("controller"), Some("\"merge_assist\""));
    }

    #[test]
    fn multi_token_vector_fields() {
        let t = "#VRML_SIM R2021a utf8\nViewpoint {\n  position 0 50 100\n}\n";
        let w = World::parse(t).unwrap();
        assert_eq!(w.find("Viewpoint").unwrap().field("position"), Some("0 50 100"));
    }

    #[test]
    fn comments_stripped() {
        let t = "#VRML_SIM R2021a utf8\nWorldInfo {\n  basicTimeStep 100 # ms\n}\n";
        let w = World::parse(t).unwrap();
        assert_eq!(
            w.find("WorldInfo").unwrap().field_u32("basicTimeStep"),
            Some(100)
        );
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::TempDir::new("webots-hpc-world").unwrap();
        let p = dir.path().join("sim.wbt");
        let w = sample_merge_world(8894);
        w.save(&p).unwrap();
        assert_eq!(World::load(&p).unwrap(), w);
    }
}
