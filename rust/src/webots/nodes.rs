//! Typed views over the scene-tree nodes the pipeline touches.

use crate::{Error, Result};

use super::world::{Node, World};

/// `WorldInfo`: global simulation parameters.  The paper's §5.3 walks
/// through the two threading knobs: the program-level 'Number of
/// Threads' preference and this node's 'Optimal Thread Count' field
/// ("roughly half the value of 'Number of Threads'").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorldInfo {
    pub basic_time_step_ms: u32,
    pub optimal_thread_count: u32,
}

impl WorldInfo {
    pub fn from_node(n: &Node) -> Result<WorldInfo> {
        Ok(WorldInfo {
            basic_time_step_ms: n
                .field_u32("basicTimeStep")
                .ok_or_else(|| Error::World("WorldInfo missing basicTimeStep".into()))?,
            optimal_thread_count: n.field_u32("optimalThreadCount").unwrap_or(1),
        })
    }

    pub fn to_node(&self) -> Node {
        Node::new("WorldInfo")
            .with_field("basicTimeStep", self.basic_time_step_ms.to_string())
            .with_field("optimalThreadCount", self.optimal_thread_count.to_string())
    }

    /// The documented guidance: optimal ≈ half the program-level thread
    /// preference (§5.3).
    pub fn recommended(number_of_threads: u32) -> WorldInfo {
        WorldInfo {
            basic_time_step_ms: 100,
            optimal_thread_count: (number_of_threads / 2).max(1),
        }
    }
}

/// The `SumoInterface` node: the Webots↔SUMO bridge.  "opposite of
/// sensors, the sampling period of the SUMO Interface must be specified
/// in the Webots user interface" (§2.5.3) — i.e. it lives in the world
/// file, which is why the copy-propagation step must edit it there.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SumoInterface {
    pub port: u16,
    pub sampling_period_ms: u32,
}

impl SumoInterface {
    pub fn from_node(n: &Node) -> Result<SumoInterface> {
        Ok(SumoInterface {
            port: n
                .field_u32("port")
                .ok_or_else(|| Error::World("SumoInterface missing port".into()))?
                as u16,
            sampling_period_ms: n.field_u32("samplingPeriod").unwrap_or(200),
        })
    }

    pub fn to_node(&self) -> Node {
        Node::new("SumoInterface")
            .with_field("port", self.port.to_string())
            .with_field("samplingPeriod", self.sampling_period_ms.to_string())
    }
}

/// Sensor declarations under a Robot node (§2.5.3 lists the suite).
#[derive(Debug, Clone, PartialEq)]
pub enum SensorSpec {
    Radar { max_range: f32 },
    Gps,
    DistanceSensor { range: f32 },
    Compass,
}

impl SensorSpec {
    pub fn from_node(n: &Node) -> Option<SensorSpec> {
        match n.node_type.as_str() {
            "Radar" => Some(SensorSpec::Radar {
                max_range: n.field_f32("maxRange").unwrap_or(150.0),
            }),
            "Gps" => Some(SensorSpec::Gps),
            "DistanceSensor" => Some(SensorSpec::DistanceSensor {
                range: n.field_f32("range").unwrap_or(10.0),
            }),
            "Compass" => Some(SensorSpec::Compass),
            _ => None,
        }
    }

    pub fn to_node(&self) -> Node {
        match self {
            SensorSpec::Radar { max_range } => {
                Node::new("Radar").with_field("maxRange", max_range.to_string())
            }
            SensorSpec::Gps => Node::new("Gps").with_field("accuracy", "0"),
            SensorSpec::DistanceSensor { range } => {
                Node::new("DistanceSensor").with_field("range", range.to_string())
            }
            SensorSpec::Compass => Node::new("Compass").with_field("resolution", "0.01"),
        }
    }
}

/// A `Robot` node: name, controller binding, sensor suite.
#[derive(Debug, Clone, PartialEq)]
pub struct RobotNode {
    pub name: String,
    pub controller: String,
    pub sensors: Vec<SensorSpec>,
}

impl RobotNode {
    pub fn from_node(n: &Node) -> Result<RobotNode> {
        let unquote = |s: &str| s.trim_matches('"').to_string();
        Ok(RobotNode {
            name: unquote(
                n.field("name")
                    .ok_or_else(|| Error::World("Robot missing name".into()))?,
            ),
            controller: unquote(n.field("controller").unwrap_or("\"void\"")),
            sensors: n.children.iter().filter_map(SensorSpec::from_node).collect(),
        })
    }

    pub fn to_node(&self) -> Node {
        let mut n = Node::new("Robot")
            .with_field("name", format!("\"{}\"", self.name))
            .with_field("controller", format!("\"{}\"", self.controller));
        for s in &self.sensors {
            n = n.with_child(s.to_node());
        }
        n
    }
}

/// The sample merge world of ch. 5: WorldInfo + Viewpoint + SumoInterface
/// + the CAV robot with its sensor suite.
pub fn sample_merge_world(port: u16) -> World {
    let mut w = World::new();
    w.nodes.push(
        WorldInfo {
            basic_time_step_ms: 100,
            optimal_thread_count: 10,
        }
        .to_node(),
    );
    w.nodes
        .push(Node::new("Viewpoint").with_field("position", "0 50 100"));
    w.nodes.push(
        SumoInterface {
            port,
            sampling_period_ms: 200,
        }
        .to_node(),
    );
    w.nodes.push(
        RobotNode {
            name: "cav_0".into(),
            controller: "merge_assist".into(),
            sensors: vec![
                SensorSpec::Radar { max_range: 150.0 },
                SensorSpec::Gps,
                SensorSpec::DistanceSensor { range: 20.0 },
            ],
        }
        .to_node(),
    );
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_info_roundtrip() {
        let wi = WorldInfo {
            basic_time_step_ms: 100,
            optimal_thread_count: 10,
        };
        assert_eq!(WorldInfo::from_node(&wi.to_node()).unwrap(), wi);
    }

    #[test]
    fn recommended_thread_count_halves() {
        assert_eq!(WorldInfo::recommended(20).optimal_thread_count, 10);
        assert_eq!(WorldInfo::recommended(1).optimal_thread_count, 1);
    }

    #[test]
    fn sumo_interface_roundtrip() {
        let si = SumoInterface {
            port: 8894,
            sampling_period_ms: 200,
        };
        assert_eq!(SumoInterface::from_node(&si.to_node()).unwrap(), si);
    }

    #[test]
    fn robot_roundtrip_with_sensors() {
        let r = RobotNode {
            name: "cav_0".into(),
            controller: "merge_assist".into(),
            sensors: vec![SensorSpec::Radar { max_range: 150.0 }, SensorSpec::Gps],
        };
        let back = RobotNode::from_node(&r.to_node()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn sample_world_is_complete() {
        let w = sample_merge_world(8873);
        let si = SumoInterface::from_node(w.find("SumoInterface").unwrap()).unwrap();
        assert_eq!(si.port, 8873);
        let robots = w.find_all("Robot");
        assert_eq!(robots.len(), 1);
        let r = RobotNode::from_node(robots[0]).unwrap();
        assert_eq!(r.controller, "merge_assist");
        assert_eq!(r.sensors.len(), 3);
    }
}
