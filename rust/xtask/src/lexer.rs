//! A small Rust lexer — just enough structure for the lint rules.
//!
//! Produces idents, single-char puncts, and literals (strings, raw
//! strings, byte strings, chars, numbers), with line numbers; comments
//! (line, nested block) and whitespace are dropped.  Lifetimes lex as
//! punct so `'a` never masquerades as a char literal.  This is NOT a
//! full lexer — no float-suffix pedantry, no shebang handling — but it
//! is exact on the constructs the rules inspect, and the fixture tests
//! pin the tricky cases (nested comments, `r#".."#`, `'a'` vs `'a`).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Lit,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

pub fn tokenize(src: &str, path: &str) -> Result<Vec<Tok>, String> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;

    let starts = |i: usize, pat: &str| -> bool {
        b[i..].iter().zip(pat.chars()).filter(|(a, c)| **a == *c).count() == pat.chars().count()
            && i + pat.chars().count() <= n
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if starts(i, "//") {
            while i < n && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        if starts(i, "/*") {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if starts(i, "/*") {
                    depth += 1;
                    i += 2;
                } else if starts(i, "*/") {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw strings: r"..." / r#"..."# / br#"..."#
        if c == 'r' || (c == 'b' && i + 1 < n && b[i + 1] == 'r') {
            let pfx = if c == 'b' { 2 } else { 1 };
            let mut j = i + pfx;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                j += 1;
                let start_line = line;
                loop {
                    if j >= n {
                        return Err(format!("{path}:{start_line}: unterminated raw string"));
                    }
                    if b[j] == '\n' {
                        line += 1;
                    }
                    if b[j] == '"' && b[j + 1..].iter().take(hashes).filter(|c| **c == '#').count() == hashes
                        && j + 1 + hashes <= n
                    {
                        j += 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Lit,
                    text: b[i..j].iter().collect(),
                    line: start_line,
                });
                i = j;
                continue;
            }
            // not a raw string: fall through to ident lexing below
        }
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let start_line = line;
            loop {
                if j >= n {
                    return Err(format!("{path}:{start_line}: unterminated string"));
                }
                if b[j] == '\\' {
                    j += 2;
                    continue;
                }
                if b[j] == '\n' {
                    line += 1;
                }
                if b[j] == '"' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Lit,
                text: b[i..=j].iter().collect(),
                line: start_line,
            });
            i = j + 1;
            continue;
        }
        if c == '\'' {
            // char literal vs lifetime: 'x' / '\n' are chars, 'a is a
            // lifetime.  A char closes with ' within a few chars; a
            // lifetime is ' + ident with no closing quote.
            if i + 2 < n && b[i + 1] == '\\' {
                let mut j = i + 3; // past the escaped char
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                if j < n {
                    toks.push(Tok {
                        kind: Kind::Lit,
                        text: b[i..=j].iter().collect(),
                        line,
                    });
                    i = j + 1;
                    continue;
                }
                return Err(format!("{path}:{line}: unterminated char"));
            }
            if i + 2 < n && b[i + 2] == '\'' {
                toks.push(Tok {
                    kind: Kind::Lit,
                    text: b[i..i + 3].iter().collect(),
                    line,
                });
                i += 3;
                continue;
            }
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let mut j = i + 2;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Punct,
                    text: b[i..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            return Err(format!("{path}:{line}: stray quote"));
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (is_ident_cont(b[j]) || b[j] == '.') {
                // `0..10` range: stop the number before `..`
                if b[j] == '.' && j + 1 < n && b[j + 1] == '.' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Lit,
                text: b[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        tokenize(src, "t.rs").unwrap().into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_strings_and_lifetimes() {
        assert_eq!(texts("a /* x /* y */ z */ b"), ["a", "b"]);
        assert_eq!(texts("let s = \"un//wrap\";"), ["let", "s", "=", "\"un//wrap\"", ";"]);
        assert_eq!(
            texts("r#\"quote \" inside\"# x"),
            ["r#\"quote \" inside\"#", "x"]
        );
        assert_eq!(texts("fn f<'a>(x: &'a str) {}").iter().filter(|t| *t == "'a").count(), 2);
        assert_eq!(texts("let c = 'x';"), ["let", "c", "=", "'x'", ";"]);
        assert_eq!(texts("let c = '\\n';"), ["let", "c", "=", "'\\n'", ";"]);
    }

    #[test]
    fn ranges_and_line_numbers() {
        assert_eq!(texts("for i in 0..10 {}"), ["for", "i", "in", "0", ".", ".", "10", "{", "}"]);
        let toks = tokenize("a\n\nb", "t.rs").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 3);
    }

    #[test]
    fn unwrap_in_comment_or_string_is_invisible() {
        let toks = tokenize("// .unwrap()\nlet x = \".expect(\";", "t.rs").unwrap();
        assert!(toks.iter().all(|t| t.kind != Kind::Ident || (t.text != "unwrap" && t.text != "expect")));
    }
}
