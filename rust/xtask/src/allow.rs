//! The allowlist: explicit, justified exemptions (rust/xtask/lint.allow).
//!
//! Format: `rule path-suffix line-substring` per line, `#` comments.
//! An entry matches a violation when the rule name is equal, the file
//! path ends with the suffix, and the flagged source line contains the
//! substring.  Every entry must match at least one violation — stale
//! entries fail the lint, so a fixed call site cannot leave a silent
//! hole behind.

use std::collections::HashMap;
use std::path::Path;

use crate::rules::Violation;

#[derive(Debug)]
pub struct AllowEntry {
    pub rule: String,
    pub suffix: String,
    pub substr: String,
    pub line_no: usize,
    pub used: bool,
}

pub fn load(path: &Path) -> Result<Vec<AllowEntry>, String> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Ok(Vec::new()); // no allowlist = no exemptions
    };
    let mut entries = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        fn field(s: &str) -> (&str, &str) {
            let s = s.trim_start();
            match s.find(char::is_whitespace) {
                Some(i) => (&s[..i], &s[i..]),
                None => (s, ""),
            }
        }
        let (rule, rest) = field(line);
        let (suffix, rest) = field(rest);
        let substr = rest.trim_start();
        if rule.is_empty() || suffix.is_empty() || substr.is_empty() {
            return Err(format!(
                "{}:{}: need `rule path-suffix line-substring`",
                path.display(),
                ln + 1
            ));
        }
        entries.push(AllowEntry {
            rule: rule.to_string(),
            suffix: suffix.to_string(),
            substr: substr.to_string(),
            line_no: ln + 1,
            used: false,
        });
    }
    Ok(entries)
}

/// Drop allowlisted violations; marks used entries.  `src_lines` maps a
/// rel path to its source lines (for the substring match).
pub fn apply(
    violations: Vec<Violation>,
    entries: &mut [AllowEntry],
    src_lines: &HashMap<String, Vec<String>>,
) -> Vec<Violation> {
    let mut kept = Vec::new();
    for v in violations {
        let line_text = src_lines
            .get(&v.path)
            .and_then(|lines| lines.get(v.line.saturating_sub(1)))
            .map(String::as_str)
            .unwrap_or("");
        let hit = entries.iter_mut().find(|e| {
            e.rule == v.rule && v.path.ends_with(&e.suffix) && line_text.contains(&e.substr)
        });
        match hit {
            Some(e) => e.used = true,
            None => kept.push(v),
        }
    }
    kept
}
