//! Test-item marking: which tokens live inside `#[cfg(test)]`-gated
//! items (at any nesting depth, anywhere in the file)?
//!
//! This is the precision the old awk gate lacked — it exempted
//! everything after the FIRST `#[cfg(test)]` in a file, so library
//! code *after* a test module escaped the print gate entirely.  Here
//! an attribute attaches to the next item, the item's extent runs to
//! its matching close brace (or `;` for bodyless items), and the cfg
//! predicate is actually evaluated: `#[cfg(test)]`, `#[cfg(any(test,
//! feature = "x"))]` etc. gate an item out of library builds only when
//! the predicate is false with `test` off — unknown predicates
//! (features, target_os, loom) conservatively count as compiled-in.

use crate::lexer::Tok;

/// Index one past the closing `]` of the attribute starting at `i`
/// (`toks[i]` must be `#`).
fn attr_end(toks: &[Tok], i: usize) -> Result<usize, String> {
    let mut j = i + 1;
    if j < toks.len() && toks[j].text == "!" {
        j += 1;
    }
    if j >= toks.len() || toks[j].text != "[" {
        return Err(format!("line {}: attribute must open with [", toks[i].line));
    }
    let mut depth = 0usize;
    while j < toks.len() {
        if toks[j].text == "[" {
            depth += 1;
        } else if toks[j].text == "]" {
            depth -= 1;
            if depth == 0 {
                return Ok(j + 1);
            }
        }
        j += 1;
    }
    Err(format!("line {}: unterminated attribute", toks[i].line))
}

/// Does the attribute in `toks[i..end)` contain a `cfg(...)` whose
/// predicate evaluates FALSE when `test` is false — i.e. gate its item
/// to test builds only?
fn cfg_requires_test(toks: &[Tok], i: usize, end: usize) -> bool {
    let texts: Vec<&str> = toks[i..end].iter().map(|t| t.text.as_str()).collect();
    let Some(k) = texts.iter().position(|t| *t == "cfg") else {
        return false;
    };
    if texts.get(k + 1) != Some(&"(") {
        return false;
    }

    // recursive-descent evaluation with test=false; unknown leaves
    // (features, target_os, loom, miri) evaluate true
    fn parse(texts: &[&str], pos: usize) -> (bool, usize) {
        let name = texts.get(pos).copied().unwrap_or(")");
        if name == "test" {
            return (false, pos + 1);
        }
        if matches!(name, "any" | "all" | "not") && texts.get(pos + 1) == Some(&"(") {
            let mut vals = Vec::new();
            let mut p = pos + 2;
            while p < texts.len() && texts[p] != ")" {
                if texts[p] == "," {
                    p += 1;
                    continue;
                }
                let (v, np) = parse(texts, p);
                vals.push(v);
                p = np;
            }
            p += 1;
            let v = match name {
                "any" => vals.iter().any(|v| *v),
                "all" => vals.iter().all(|v| *v),
                _ => !vals.first().copied().unwrap_or(false),
            };
            return (v, p);
        }
        // feature = "...", target_os = "...", miri, loom → unknown
        let mut p = pos + 1;
        while p < texts.len() && texts[p] != "," && texts[p] != ")" {
            p += 1;
        }
        (true, p)
    }

    let (val, _) = parse(&texts, k + 2);
    !val
}

/// One bool per token: is it inside a test-gated item?
pub fn mark_test_tokens(toks: &[Tok]) -> Result<Vec<bool>, String> {
    let n = toks.len();
    let mut in_test = vec![false; n];
    let mut i = 0usize;
    let mut pending_test = false;
    let mut depth = 0usize;
    let mut test_depths: Vec<usize> = Vec::new();

    while i < n {
        let t = &toks[i];
        if t.text == "#"
            && t.kind == crate::lexer::Kind::Punct
            && i + 1 < n
            && (toks[i + 1].text == "[" || toks[i + 1].text == "!")
        {
            let end = attr_end(toks, i)?;
            let is_test = cfg_requires_test(toks, i, end);
            let inner = toks[i + 1].text == "!";
            if !test_depths.is_empty() {
                for k in in_test.iter_mut().take(end).skip(i) {
                    *k = true;
                }
            }
            if is_test && !inner {
                pending_test = true;
                // the attribute tokens themselves are test-only too
                for k in in_test.iter_mut().take(end).skip(i) {
                    *k = true;
                }
            }
            i = end;
            continue;
        }
        if !test_depths.is_empty() {
            in_test[i] = true;
        }
        if t.text == "{" {
            depth += 1;
            if pending_test {
                test_depths.push(depth);
                in_test[i] = true;
                pending_test = false;
            }
        } else if t.text == "}" {
            if test_depths.last() == Some(&depth) {
                test_depths.pop();
                in_test[i] = true;
            }
            depth = depth.saturating_sub(1);
        } else if t.text == ";"
            && pending_test
            && depth == test_depths.last().copied().unwrap_or(0)
        {
            // `#[cfg(test)] use foo;` — extent ended without a body
            pending_test = false;
            in_test[i] = true;
        }
        i += 1;
    }
    Ok(in_test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::tokenize;

    fn test_idents(src: &str) -> Vec<(String, bool)> {
        let toks = tokenize(src, "t.rs").unwrap();
        let marks = mark_test_tokens(&toks).unwrap();
        toks.iter()
            .zip(&marks)
            .filter(|(t, _)| t.kind == crate::lexer::Kind::Ident)
            .map(|(t, m)| (t.text.clone(), *m))
            .collect()
    }

    #[test]
    fn library_code_after_a_test_mod_is_not_exempt() {
        // the exact hole in the old awk gate
        let src = "#[cfg(test)]\nmod tests { fn a() {} }\nfn lib() { b(); }";
        let ids = test_idents(src);
        assert!(ids.iter().any(|(t, m)| t == "a" && *m));
        assert!(ids.iter().any(|(t, m)| t == "lib" && !*m));
        assert!(ids.iter().any(|(t, m)| t == "b" && !*m));
    }

    #[test]
    fn cfg_predicates_evaluate() {
        // any(test, loom): loom is unknown → compiled-in → NOT test-only
        let ids = test_idents("#[cfg(any(test, loom))]\nfn f() { g(); }");
        assert!(ids.iter().any(|(t, m)| t == "g" && !*m));
        // all(test, unix): test=false makes all() false → test-only
        let ids = test_idents("#[cfg(all(test, unix))]\nfn f() { g(); }");
        assert!(ids.iter().any(|(t, m)| t == "g" && *m));
        // not(test) → compiled-in
        let ids = test_idents("#[cfg(not(test))]\nfn f() { g(); }");
        assert!(ids.iter().any(|(t, m)| t == "g" && !*m));
    }

    #[test]
    fn test_attr_marks_the_next_item_only() {
        let src = "#[test]\nfn t() { x(); }\nfn lib() { y(); }";
        // #[test] is not cfg(test); only #[cfg(test)] gates compilation.
        // The lint treats #[test] fns via their enclosing cfg(test) mod,
        // so a bare #[test] at top level stays covered (conservative).
        let ids = test_idents(src);
        assert!(ids.iter().any(|(t, m)| t == "x" && !*m));
        assert!(ids.iter().any(|(t, m)| t == "y" && !*m));
    }

    #[test]
    fn bodyless_cfg_test_items() {
        let ids = test_idents("#[cfg(test)]\nuse foo::bar;\nfn lib() { baz(); }");
        assert!(ids.iter().any(|(t, m)| t == "bar" && *m));
        assert!(ids.iter().any(|(t, m)| t == "baz" && !*m));
    }
}
