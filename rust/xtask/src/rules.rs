//! The four analysis rules.  Each takes the token stream + test-item
//! marking for one file and appends [`Violation`]s.  Rule semantics
//! are pinned by the fixture tests below AND mirrored in
//! scripts/lint_mirror.py for toolchain-less machines — change both.

use crate::config::*;
use crate::items::mark_test_tokens;
use crate::lexer::{tokenize, Kind, Tok};

#[derive(Debug, Clone)]
pub struct Violation {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.msg)
    }
}

/// Run every per-file rule over one source file (`rel` is the path
/// relative to the src root, with forward slashes).
pub fn lint_source(rel: &str, src: &str) -> Result<Vec<Violation>, String> {
    let toks = tokenize(src, rel)?;
    let in_test = mark_test_tokens(&toks)?;
    let mut out = Vec::new();
    panic_freedom(rel, &toks, &in_test, &mut out);
    print_freedom(rel, &toks, &in_test, &mut out);
    lock_discipline(rel, &toks, &in_test, &mut out);
    ledger_order(rel, &toks, &in_test, &mut out);
    Ok(out)
}

fn base_name(rel: &str) -> &str {
    rel.rsplit('/').next().unwrap_or(rel)
}

// ---------------------------------------------------------------- rule 2

pub fn panic_freedom(rel: &str, toks: &[Tok], in_test: &[bool], out: &mut Vec<Violation>) {
    if PANIC_SKIP_FILES.contains(&base_name(rel)) {
        return;
    }
    let n = toks.len();
    for (i, t) in toks.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        if t.kind == Kind::Ident && (t.text == "unwrap" || t.text == "expect") {
            let method = i > 0 && toks[i - 1].text == ".";
            let called = i + 1 < n && toks[i + 1].text == "(";
            if method && called {
                out.push(Violation {
                    rule: "panic-freedom",
                    path: rel.to_string(),
                    line: t.line,
                    msg: format!(
                        ".{}() can panic in library code — return Result, \
                         recover (unwrap_or_else), or allowlist with a justification",
                        t.text
                    ),
                });
            }
        }
    }
    if !INDEXING_DIRS.iter().any(|d| rel.starts_with(d)) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if in_test[i] || t.text != "[" || i == 0 {
            continue;
        }
        let prev = &toks[i - 1];
        // an index expression follows a value: ident, `)`, `]` or a
        // literal... except that `#[attr]`, array literals `= [`,
        // `vec![`, and types `[u8; 4]` follow punctuation or a macro
        // bang instead.
        if prev.text == "!" || (prev.kind == Kind::Punct && prev.text != ")" && prev.text != "]") {
            continue;
        }
        if prev.kind == Kind::Lit {
            continue;
        }
        if prev.kind == Kind::Ident
            && matches!(prev.text.as_str(), "return" | "in" | "break" | "mut" | "else" | "match" | "vec")
        {
            continue;
        }
        out.push(Violation {
            rule: "panic-freedom",
            path: rel.to_string(),
            line: t.line,
            msg: "indexing can panic in control-plane code — use .get()/.get_mut() \
                  or allowlist with a bounds argument"
                .to_string(),
        });
    }
}

// ---------------------------------------------------------------- rule 3

pub fn print_freedom(rel: &str, toks: &[Tok], in_test: &[bool], out: &mut Vec<Violation>) {
    if PRINT_SKIP_FILES.contains(&base_name(rel)) || PRINT_SKIP_DIRS.iter().any(|d| rel.starts_with(d))
    {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if in_test[i] {
            continue;
        }
        if t.kind == Kind::Ident
            && PRINT_MACROS.contains(&t.text.as_str())
            && i + 1 < toks.len()
            && toks[i + 1].text == "!"
        {
            out.push(Violation {
                rule: "print-freedom",
                path: rel.to_string(),
                line: t.line,
                msg: format!(
                    "{}! in library code — emit a telemetry event or metric \
                     instead (stdout vanishes in batch campaigns)",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- rule 1

/// If `toks[i]` opens a call `name(`, return the name.
fn call_name(toks: &[Tok], i: usize) -> Option<&str> {
    let t = toks.get(i)?;
    if t.kind != Kind::Ident {
        return None;
    }
    if toks.get(i + 1)?.text == "(" {
        Some(&t.text)
    } else {
        None
    }
}

pub fn lock_discipline(rel: &str, toks: &[Tok], in_test: &[bool], out: &mut Vec<Violation>) {
    if !LOCK_FILES.iter().any(|f| rel.ends_with(f)) {
        return;
    }
    let n = toks.len();

    // statement-level scan with a scope stack of live guards:
    //   let g = lock(&x);            — named guard, lives to drop/scope end
    //   lock(&x).field += 1;         — temporary, lives to end of statement
    //   match lock(&x) { ... }       — temporary, lives for the block
    let mut guards: Vec<(String, usize)> = Vec::new(); // (name, depth)
    let mut pending_temp: Vec<usize> = Vec::new(); // block-scoped temporaries (depth)
    let mut depth = 0usize;
    let mut stmt_has_let = false;
    let mut let_name: Option<String> = None;
    let mut stmt_acquired: Option<usize> = None; // line of in-statement acquisition
    let mut i = 0usize;

    macro_rules! deny_check {
        ($idx:expr) => {
            if let Some(name) = call_name(toks, $idx) {
                if DENY_UNDER_GUARD.contains(&name)
                    && (!guards.is_empty() || !pending_temp.is_empty() || stmt_acquired.is_some())
                {
                    let hold = guards
                        .last()
                        .map(|g| g.0.clone())
                        .unwrap_or_else(|| "<temporary>".to_string());
                    out.push(Violation {
                        rule: "lock-discipline",
                        path: rel.to_string(),
                        line: toks[$idx].line,
                        msg: format!(
                            "`{name}(...)` while guard `{hold}` from lock() is live — \
                             release the dispatch mutex before blocking work"
                        ),
                    });
                }
            }
        };
    }

    while i < n {
        let t = &toks[i];
        if in_test[i] {
            i += 1;
            continue;
        }
        if t.text == "{" {
            depth += 1;
            if stmt_acquired.take().is_some() {
                // `match lock(&x) { ... }` / `if let ... = lock(&x) {`:
                // the temporary lives for the attached block
                pending_temp.push(depth);
            }
            stmt_has_let = false;
            let_name = None;
            i += 1;
            continue;
        }
        if t.text == "}" {
            guards.retain(|g| g.1 < depth);
            pending_temp.retain(|d| *d < depth);
            // a tail-expression temporary (`fn f() { x.lock() }`) dies
            // with its block
            stmt_acquired = None;
            depth = depth.saturating_sub(1);
            i += 1;
            continue;
        }
        if t.text == ";" {
            if stmt_acquired.take().is_some() && stmt_has_let {
                if let Some(name) = let_name.take() {
                    if name != "_" {
                        guards.push((name, depth));
                    }
                }
            }
            stmt_has_let = false;
            let_name = None;
            stmt_acquired = None;
            i += 1;
            continue;
        }
        if t.kind == Kind::Ident && t.text == "let" {
            stmt_has_let = true;
            // pattern: let [mut] NAME =
            let mut j = i + 1;
            if j < n && toks[j].text == "mut" {
                j += 1;
            }
            if j < n && toks[j].kind == Kind::Ident {
                let_name = Some(toks[j].text.clone());
            }
            i += 1;
            continue;
        }
        if t.kind == Kind::Ident && t.text == "drop" && i + 1 < n && toks[i + 1].text == "(" {
            if i + 2 < n && toks[i + 2].kind == Kind::Ident {
                let victim = toks[i + 2].text.clone();
                guards.retain(|g| g.0 != victim);
            }
            i += 1;
            continue;
        }
        if let Some(name) = call_name(toks, i) {
            if GUARD_CALLS.contains(&name) {
                let prev_dot = i > 0 && toks[i - 1].text == ".";
                if name == "lock" || prev_dot {
                    deny_check!(i); // nested acquisition under a live guard
                    stmt_acquired = Some(t.line);
                    i += 1;
                    continue;
                }
            }
        }
        deny_check!(i);
        i += 1;
    }
}

// ---------------------------------------------------------------- rule 4

pub fn ledger_order(rel: &str, toks: &[Tok], in_test: &[bool], out: &mut Vec<Violation>) {
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if toks[i].kind == Kind::Ident && toks[i].text == "fn" && !in_test[i] {
            // find the body's open brace (skip bodyless decls)
            let mut j = i + 1;
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j >= n || toks[j].text == ";" {
                i = j + 1;
                continue;
            }
            let mut depth = 1usize;
            let mut k = j + 1;
            let mut synced = false;
            while k < n && depth > 0 {
                let tk = &toks[k];
                if tk.text == "{" {
                    depth += 1;
                } else if tk.text == "}" {
                    depth -= 1;
                } else if tk.kind == Kind::Ident && LEDGER_SYNC_CALLS.contains(&tk.text.as_str()) {
                    synced = true;
                } else if tk.kind == Kind::Ident
                    && LEDGER_EMIT_CALLS.contains(&tk.text.as_str())
                    && k + 1 < n
                    && toks[k + 1].text == "("
                {
                    // scan the emit(...) argument list for the event kind
                    let mut pdepth = 1usize;
                    let mut m = k + 2;
                    let mut hit: Option<usize> = None;
                    while m < n && pdepth > 0 {
                        if toks[m].text == "(" {
                            pdepth += 1;
                        } else if toks[m].text == ")" {
                            pdepth -= 1;
                        } else if toks[m].kind == Kind::Ident && toks[m].text == LEDGER_EVENT {
                            hit = Some(toks[m].line);
                        }
                        m += 1;
                    }
                    if let Some(line) = hit {
                        if !synced {
                            out.push(Violation {
                                rule: "ledger-before-event",
                                path: rel.to_string(),
                                line,
                                msg: "LedgerTransition emitted with no preceding fsync \
                                      in this fn — events must never lead the durable \
                                      ledger (events ⊇ ledger contract)"
                                    .to_string(),
                            });
                        }
                    }
                    k = m - 1;
                }
                k += 1;
            }
            i = k;
            continue;
        }
        i += 1;
    }
}

// ---------------------------------------------------------------- rule 5

/// The module roots that must keep the clippy unwrap/expect gate.
pub fn deny_attr(root: &std::path::Path, out: &mut Vec<Violation>) {
    for rel in DENY_ATTR_FILES {
        let p = root.join(rel);
        match std::fs::read_to_string(&p) {
            Err(_) => out.push(Violation {
                rule: "deny-attr",
                path: rel.to_string(),
                line: 0,
                msg: "module root missing".to_string(),
            }),
            Ok(src) => {
                if !src.contains(DENY_ATTR) {
                    out.push(Violation {
                        rule: "deny-attr",
                        path: rel.to_string(),
                        line: 1,
                        msg: format!("module root lost its `#![{DENY_ATTR}]` gate"),
                    });
                }
            }
        }
    }
}
