//! Rule configuration — which files each rule covers and which calls it
//! tracks.  scripts/lint_mirror.py mirrors these tables verbatim so a
//! toolchain-less machine can run the same lint; keep them in sync.

/// panic-freedom: deny `.unwrap()`/`.expect()` in every library module.
/// main.rs is the CLI binary (aborting with a message is its job); test
/// items are exempt at item-tree level, not by filename.
pub const PANIC_SKIP_FILES: &[&str] = &["main.rs"];

/// indexing-panics are denied only in the concurrency-heavy control
/// plane, where a panic aborts an unattended campaign; numeric hot-path
/// modules (sumo/, runtime/ kernels) index slices pervasively and are
/// covered by bounds-checked accessors + tests instead.
pub const INDEXING_DIRS: &[&str] = &["fabric/", "pipeline/", "telemetry/"];

/// print-freedom: library observability goes through telemetry; stray
/// prints vanish in batch campaigns.  main.rs is the CLI; harness/ and
/// metrics/ are operator-facing table writers.
pub const PRINT_SKIP_FILES: &[&str] = &["main.rs"];
pub const PRINT_SKIP_DIRS: &[&str] = &["harness/", "metrics/"];
pub const PRINT_MACROS: &[&str] = &["println", "eprintln", "print", "eprint", "dbg"];

/// lock-discipline: while a guard from one of GUARD_CALLS is live, none
/// of DENY_UNDER_GUARD may be reached — blocking I/O, fsync, sleeps,
/// nested locks, telemetry flushes: anything that can stall the
/// dispatch mutex every worker connection and the reaper serialize on.
/// `telemetry/sink.rs` is covered for its sink-registry RwLock (no sink
/// emit/flush under it — fan-out runs on an Arc snapshot); `read`/
/// `write` as guard calls also make the classic RwLock read→write
/// upgrade deadlock a lint error.  fabric/worker.rs is deliberately NOT
/// covered: its writer mutex exists to make frame writes atomic, so
/// writing under it is the design (EXPERIMENTS.md §Static analysis).
pub const LOCK_FILES: &[&str] = &["fabric/coordinator.rs", "telemetry/sink.rs"];
pub const GUARD_CALLS: &[&str] = &["lock", "read", "write"];
pub const DENY_UNDER_GUARD: &[&str] = &[
    "sleep",
    "sync_all",
    "sync_data",
    "flush",
    "flush_all",
    "write_all",
    "write_msg",
    "supervise_instance",
    "publish_run_csv",
    "mark_running",
    "mark_completed",
    "mark_failed",
    "emit",
    "read",
    "read_line",
    "write",
    "assemble_aggregate",
    "plan_run",
    "lock_ledger",
];

/// ledger-before-event: every telemetry emit of a LedgerTransition must
/// be dominated (same fn body, earlier token) by the durability fsync.
/// Only `emit(...)` argument positions count — LedgerTransition in
/// match arms, parsers, and constructors elsewhere is fine.
pub const LEDGER_EVENT: &str = "LedgerTransition";
pub const LEDGER_EMIT_CALLS: &[&str] = &["emit"];
pub const LEDGER_SYNC_CALLS: &[&str] = &["sync_data", "sync_all"];

/// deny-attribute presence: these module roots must keep the clippy
/// gate (the AST lint and clippy double-cover unwrap/expect; clippy
/// additionally understands type-level dataflow the lexer cannot).
pub const DENY_ATTR_FILES: &[&str] = &[
    "fabric/mod.rs",
    "pipeline/mod.rs",
    "telemetry/mod.rs",
    "runtime/mod.rs",
    "traci/mod.rs",
    "display/mod.rs",
];
pub const DENY_ATTR: &str = "deny(clippy::unwrap_used, clippy::expect_used)";
