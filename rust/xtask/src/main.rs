//! webots-hpc-lint — the project's AST-accurate static-analysis gate.
//!
//! ```text
//! cargo run -p xtask -- lint            # lint rust/src against lint.allow
//! cargo run -p xtask -- lint <src-dir>  # lint another tree (fixtures, CI)
//! ```
//!
//! Four rules over a hand-rolled lexer + item tree (no syn, no deps —
//! see Cargo.toml for why):
//!
//! 1. **lock-discipline** — in `fabric/coordinator.rs` and
//!    `telemetry/sink.rs`, no blocking call (fsync, socket write,
//!    sleep, ledger op, telemetry emit/flush, nested lock) while a
//!    guard from `lock()`/`.read()`/`.write()` is live — including the
//!    RwLock read→write upgrade deadlock.  This is the machine-checked
//!    form of the settlement race PR 8's review caught by hand.
//! 2. **panic-freedom** — `.unwrap()`/`.expect()` denied in every
//!    library module; indexing additionally denied in the control
//!    plane (fabric/, pipeline/, telemetry/).  Exemptions live in
//!    `lint.allow` with a written justification; stale entries fail.
//! 3. **print-freedom** — `println!`-family and `dbg!` denied in
//!    library code, honoring `#[cfg(test)]` items anywhere in a file
//!    (the old awk gate exempted everything after the first match).
//! 4. **ledger-before-event** — a `LedgerTransition` may only be
//!    passed to `emit(...)` in a fn that fsyncs first: telemetry is a
//!    superset of the ledger, never ahead of it.
//!
//! Plus a presence check that the six gated module roots keep their
//! `#![deny(clippy::unwrap_used, clippy::expect_used)]` attribute.
//!
//! Exit codes: 0 clean · 1 violations/stale-allowlist · 2 usage or
//! internal error.  scripts/lint_mirror.py is a line-for-line python
//! mirror for machines without a rust toolchain.

mod allow;
mod config;
mod items;
mod lexer;
mod rules;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => match run_lint(args.get(1).map(String::as_str)) {
            Ok(true) => ExitCode::SUCCESS,
            Ok(false) => ExitCode::from(1),
            Err(e) => {
                eprintln!("xtask lint: {e}");
                ExitCode::from(2)
            }
        },
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [src-root]");
            ExitCode::from(2)
        }
    }
}

/// Lint `root` (default: the repo's rust/src, resolved relative to this
/// crate so the command works from any cwd).  Returns Ok(true) when
/// clean.
fn run_lint(root_arg: Option<&str>) -> Result<bool, String> {
    let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let root = match root_arg {
        Some(p) => PathBuf::from(p),
        None => manifest_dir
            .parent()
            .ok_or("xtask crate has no parent directory")?
            .join("src"),
    };
    if !root.is_dir() {
        return Err(format!("src root {} is not a directory", root.display()));
    }
    let allow_path = manifest_dir.join("lint.allow");

    let (violations, stale) = lint_tree(&root, &allow_path)?;
    for v in &violations {
        println!("{v}");
    }
    for e in &stale {
        eprintln!(
            "lint.allow:{}: stale allowlist entry ({} {} {:?}) matched nothing",
            e.line_no, e.rule, e.suffix, e.substr
        );
    }
    if violations.is_empty() && stale.is_empty() {
        println!("xtask lint: clean");
        Ok(true)
    } else {
        eprintln!(
            "\nxtask lint: {} violation(s), {} stale allowlist entr(ies)",
            violations.len(),
            stale.len()
        );
        Ok(false)
    }
}

/// Walk every `.rs` file under `root`, run the rules, apply the
/// allowlist.  Returns (surviving violations, stale allow entries).
fn lint_tree(
    root: &Path,
    allow_path: &Path,
) -> Result<(Vec<rules::Violation>, Vec<allow::AllowEntry>), String> {
    let mut files = Vec::new();
    collect_rs(root, &mut files).map_err(|e| e.to_string())?;
    files.sort();

    let mut violations = Vec::new();
    let mut src_lines: HashMap<String, Vec<String>> = HashMap::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|e| e.to_string())?
            .to_string_lossy()
            .replace(std::path::MAIN_SEPARATOR, "/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        violations.extend(rules::lint_source(&rel, &src)?);
        src_lines.insert(rel, src.lines().map(str::to_string).collect());
    }
    rules::deny_attr(root, &mut violations);

    let mut entries = allow::load(allow_path)?;
    let violations = allow::apply(violations, &mut entries, &src_lines);
    let stale = entries.into_iter().filter(|e| !e.used).collect();
    Ok((violations, stale))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

// ------------------------------------------------------------------------
// self-tests: each rule must catch its seeded fixture violation, and the
// real tree must lint clean — a silently-broken analyzer fails the gate.
// ------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(name: &str) -> String {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("fixtures")
            .join(name);
        std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
    }

    fn rules_of(rel: &str, src: &str) -> Vec<rules::Violation> {
        rules::lint_source(rel, src).expect("fixture must tokenize")
    }

    #[test]
    fn panic_fixture_is_caught() {
        let v = rules_of("pipeline/seeded.rs", &fixture("seeded_panic.rs"));
        let panics: Vec<_> = v.iter().filter(|v| v.rule == "panic-freedom").collect();
        // exactly the seeded sites: one .unwrap(), one .expect(), one
        // index — and NOT the test-mod or allow-pattern decoys
        assert_eq!(panics.len(), 3, "{panics:?}");
        assert!(panics.iter().any(|v| v.msg.contains(".unwrap()")));
        assert!(panics.iter().any(|v| v.msg.contains(".expect()")));
        assert!(panics.iter().any(|v| v.msg.contains("indexing")));
    }

    #[test]
    fn indexing_only_flagged_in_control_plane() {
        let src = "fn f(v: &[u32]) -> u32 { v[0] }";
        assert_eq!(rules_of("pipeline/x.rs", src).len(), 1);
        assert_eq!(rules_of("sumo/x.rs", src).len(), 0);
    }

    #[test]
    fn print_fixture_is_caught() {
        let v = rules_of("telemetry/seeded.rs", &fixture("seeded_print.rs"));
        let prints: Vec<_> = v.iter().filter(|v| v.rule == "print-freedom").collect();
        // the library println! and the dbg! — not the #[cfg(test)] one,
        // not the string literal, not the trailing-library-fn hole
        assert_eq!(prints.len(), 3, "{prints:?}");
        assert!(prints.iter().any(|v| v.msg.starts_with("println!")));
        assert!(prints.iter().any(|v| v.msg.starts_with("dbg!")));
        assert!(prints.iter().any(|v| v.line > 20), "post-test-mod library code must stay covered");
    }

    #[test]
    fn lock_fixture_is_caught() {
        let v = rules_of("fabric/coordinator.rs", &fixture("seeded_lock.rs"));
        let locks: Vec<_> = v.iter().filter(|v| v.rule == "lock-discipline").collect();
        // named-guard fsync, temporary-guard emit, block-temporary
        // write_all, nested lock_ledger — the drop()-then-emit and
        // scoped-release patterns must NOT be flagged
        assert_eq!(locks.len(), 4, "{locks:?}");
        assert!(locks.iter().any(|v| v.msg.contains("sync_data")));
        assert!(locks.iter().any(|v| v.msg.contains("emit")));
        assert!(locks.iter().any(|v| v.msg.contains("write_all")));
        assert!(locks.iter().any(|v| v.msg.contains("lock_ledger")));
    }

    #[test]
    fn sink_fixture_is_caught() {
        let v = rules_of("telemetry/sink.rs", &fixture("seeded_sink.rs"));
        let locks: Vec<_> = v.iter().filter(|v| v.rule == "lock-discipline").collect();
        // flush under a named read guard, emit on a read temporary, and
        // the read→write upgrade deadlock — the snapshot-then-fan-out
        // shape must NOT be flagged
        assert_eq!(locks.len(), 3, "{locks:?}");
        assert!(locks.iter().any(|v| v.msg.contains("flush")));
        assert!(locks.iter().any(|v| v.msg.contains("emit")));
        assert!(locks.iter().any(|v| v.msg.contains("`write`") || v.msg.contains("write(")));
    }

    #[test]
    fn lock_rule_only_covers_configured_files() {
        let src = "fn f() { let g = lock(&s); g.ledger.sync_data(); }";
        assert_eq!(rules_of("fabric/coordinator.rs", src).len(), 1);
        // worker.rs writes frames under its writer mutex by design
        assert_eq!(rules_of("fabric/worker.rs", src).len(), 0);
    }

    #[test]
    fn ledger_fixture_is_caught() {
        let v = rules_of("telemetry/seeded.rs", &fixture("seeded_ledger.rs"));
        let leds: Vec<_> = v.iter().filter(|v| v.rule == "ledger-before-event").collect();
        // the unsynced emit only — not the post-fsync emit, not the
        // match-arm constructor use
        assert_eq!(leds.len(), 1, "{leds:?}");
    }

    #[test]
    fn deny_attr_checks_module_roots() {
        let dir = std::env::temp_dir().join(format!("xtask_deny_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for rel in config::DENY_ATTR_FILES {
            let p = dir.join(rel);
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(&p, format!("#![{}]\n", config::DENY_ATTR)).unwrap();
        }
        let mut v = Vec::new();
        rules::deny_attr(&dir, &mut v);
        assert!(v.is_empty(), "{v:?}");
        // strip one gate → one violation
        std::fs::write(dir.join("fabric/mod.rs"), "pub mod lease;\n").unwrap();
        let mut v = Vec::new();
        rules::deny_attr(&dir, &mut v);
        assert_eq!(v.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn allowlist_suppresses_and_reports_stale() {
        let dir = std::env::temp_dir().join(format!("xtask_allow_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("pipeline")).unwrap();
        std::fs::write(
            dir.join("pipeline/a.rs"),
            "fn f(v: &[u32]) -> u32 { v[justified_index()] }\n",
        )
        .unwrap();
        for rel in config::DENY_ATTR_FILES {
            let p = dir.join(rel);
            std::fs::create_dir_all(p.parent().unwrap()).unwrap();
            std::fs::write(&p, format!("#![{}]\n", config::DENY_ATTR)).unwrap();
        }
        let allow = dir.join("lint.allow");
        std::fs::write(
            &allow,
            "panic-freedom pipeline/a.rs justified_index\n\
             panic-freedom pipeline/a.rs this_site_was_fixed\n",
        )
        .unwrap();
        let (violations, stale) = lint_tree(&dir, &allow).unwrap();
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(stale.len(), 1, "the fixed site's entry must go stale");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The gate's own regression test: the real tree must be clean.
    /// In particular `fabric/coordinator.rs` — the PR 9 refactor moved
    /// every ledger fsync, CSV publish, socket write, and telemetry
    /// emit outside the dispatch mutex, and this pins it that way.
    #[test]
    fn real_tree_is_clean() {
        let manifest_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let root = manifest_dir.parent().unwrap().join("src");
        let allow = manifest_dir.join("lint.allow");
        let (violations, stale) = lint_tree(&root, &allow).unwrap();
        assert!(
            violations.is_empty(),
            "rust/src must lint clean:\n{}",
            violations
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(stale.is_empty(), "stale allowlist entries: {stale:?}");
    }
}
