//! Seeded panic-freedom fixture.  Linted by the self-tests under the
//! pretend path `pipeline/seeded.rs` (a control-plane dir, so indexing
//! is denied too).  NOT compiled into any crate.  Expected hits: one
//! `.unwrap()`, one `.expect(`, one index expression — and nothing
//! from the test mod, the comment, or the string literal.

pub fn unchecked(v: &[u32]) -> u32 {
    let first = v.first().unwrap(); // seeded: .unwrap()
    let second = v.get(1).expect("fixture"); // seeded: .expect()
    *first + *second + v[2] // seeded: indexing in a control-plane dir
}

pub fn fine(v: &[u32]) -> u32 {
    // mentions in comments and strings are invisible: .unwrap() v[0]
    let s = ".expect(";
    v.first().copied().unwrap_or(0) + s.len() as u32
}

#[cfg(test)]
mod tests {
    #[test]
    fn decoy() {
        let v = vec![1u32, 2];
        assert_eq!(*v.first().unwrap(), v[0]); // exempt: cfg(test)
    }
}
