//! Seeded lock-discipline fixture.  Linted by the self-tests under the
//! pretend path `fabric/coordinator.rs`.  NOT compiled into any crate.
//! Expected hits: fsync under a named guard, emit on a statement
//! temporary, socket write inside a `match lock(..)` block, and the
//! ledger mutex nested under the dispatch mutex.  The drop-then-emit
//! and scoped-release shapes below are the sanctioned patterns and
//! must stay clean.

pub fn named_guard_fsync(shared: &Mutex<Shared>, file: &File) {
    let g = lock(shared);
    let _ = file.sync_data(); // seeded: fsync while `g` is live
    drop(g);
}

pub fn temporary_guard_emit(shared: &Mutex<Shared>) {
    lock(shared).registry.emit(Event::WorkerJoin); // seeded: emit on a live temporary
}

pub fn block_temporary_write(shared: &Mutex<Shared>, sock: &mut TcpStream) {
    match lock(shared).queue.pop_front() {
        Some(idx) => {
            let _ = sock.write_all(b"lease"); // seeded: socket write, temporary lives for the match
            let _ = idx;
        }
        None => {}
    }
}

pub fn nested_ledger_lock(shared: &Mutex<Shared>, ledger: &Mutex<CampaignLedger>) {
    let g = lock(shared);
    let mut led = lock_ledger(ledger); // seeded: ledger mutex nested under dispatch mutex
    led.touch();
    drop(led);
    drop(g);
}

pub fn drop_then_emit(shared: &Mutex<Shared>, registry: &Registry) {
    let g = lock(shared);
    let n = g.stats.completed;
    drop(g);
    registry.emit(Event::RunEnd(n)); // fine: guard released first
}

pub fn scoped_release(shared: &Mutex<Shared>, file: &File) {
    let n = {
        let g = lock(shared);
        g.stats.completed
    };
    let _ = file.sync_data(); // fine: guard died with the inner scope
    let _ = n;
}
