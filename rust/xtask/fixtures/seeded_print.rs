//! Seeded print-freedom fixture.  Linted by the self-tests under the
//! pretend path `telemetry/seeded.rs`.  NOT compiled into any crate.
//! Expected hits: the library `println!`, the `dbg!`, and the
//! post-test-mod `eprintln!` — the last one is the exact hole the old
//! awk gate had (it exempted everything after the first `#[cfg(test)]`
//! in a file).

pub fn chatty(n: u64) {
    println!("progress: {n}"); // seeded: library println!
    let _ = dbg!(n); // seeded: dbg!
}

pub fn quiet() -> &'static str {
    "println!(\"this is a string, not a call\")"
}

#[cfg(test)]
mod tests {
    #[test]
    fn decoy() {
        println!("test output is fine"); // exempt: cfg(test)
    }
}

pub fn trailing(n: u64) {
    eprintln!("late: {n}"); // seeded: post-test-mod library print
}
