//! Seeded lock-discipline fixture for the sink-registry RwLock.
//! Linted by the self-tests under the pretend path `telemetry/sink.rs`.
//! NOT compiled into any crate.  Expected hits: a sink flush under a
//! named read guard, an emit on a read temporary, and a write-lock
//! acquisition nested under a live read guard (the RwLock upgrade
//! deadlock).  The snapshot-then-fan-out shape below is the sanctioned
//! pattern and must stay clean.

pub fn flush_under_read_guard(registry: &RwLock<Vec<Sink>>) {
    let g = registry.read();
    for s in g.iter() {
        s.flush(); // seeded: flush while the registry read guard is live
    }
    drop(g);
}

pub fn emit_on_read_temporary(registry: &RwLock<Vec<Sink>>, ev: &Event) {
    registry.read().fanout.emit(ev); // seeded: emit on a live temporary
}

pub fn upgrade_deadlock(registry: &RwLock<Vec<Sink>>) {
    let g = registry.read();
    let mut w = registry.write(); // seeded: read→write upgrade deadlocks
    w.clear();
    drop(w);
    drop(g);
}

pub fn snapshot_then_fanout(registry: &RwLock<Arc<Vec<Sink>>>, ev: &Event) {
    let snap = {
        let g = registry.read();
        g.clone()
    };
    for s in snap.iter() {
        s.emit(ev); // fine: the guard died with the inner scope
        s.flush(); // fine: fan-out runs on the snapshot, lock released
    }
}
