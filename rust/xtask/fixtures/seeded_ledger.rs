//! Seeded ledger-before-event fixture.  Linted by the self-tests under
//! the pretend path `telemetry/seeded.rs`.  NOT compiled into any
//! crate.  Expected hits: exactly the un-fsynced emit — the post-fsync
//! emit and the plain constructor use in a match are legal.

pub fn event_without_fsync(registry: &Registry) {
    registry.emit(Event::Ledger(LedgerTransition::RunCompleted)); // seeded: no fsync in this fn
}

pub fn event_after_fsync(registry: &Registry, file: &File) -> io::Result<()> {
    file.sync_data()?;
    registry.emit(Event::Ledger(LedgerTransition::RunCompleted)); // fine: durable first
    Ok(())
}

pub fn constructor_in_match(kind: u8) -> Option<LedgerTransition> {
    match kind {
        0 => Some(LedgerTransition::RunBegin),
        _ => None,
    }
}
