//! Cross-language numerics: the AOT JAX/Pallas artifact executed via
//! PJRT must agree with the pure-rust port of the same model (which in
//! turn mirrors `python/compile/kernels/ref.py`, the pytest oracle).
//!
//! This closes the validation triangle:
//!   pallas kernel ≈ jnp ref  (pytest, python/tests)
//!   jnp model     ≈ rust native (THIS file, via the lowered HLO)
//! so rust-native ≈ pallas transitively.
//!
//! All tests no-op with a note when `make artifacts` hasn't run.

use webots_hpc::runtime::EngineService;
use webots_hpc::sumo::idm::idm_accel_all;
use webots_hpc::sumo::state::{DriverParams, Traffic};
use webots_hpc::sumo::{NativeIdmStepper, Stepper};
use webots_hpc::util::Rng64;

fn service() -> Option<EngineService> {
    match EngineService::auto() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime numerics: {e}");
            None
        }
    }
}

/// Random-but-plausible traffic in a bucket.
fn random_traffic(rng: &mut Rng64, cap: usize, fill: f64) -> Traffic {
    let mut t = Traffic::new(cap);
    let mut x = 0.0f32;
    for i in 0..cap {
        if rng.gen_f64() >= fill {
            continue;
        }
        x += 8.0 + rng.gen_range_f32(0.0, 60.0);
        let lane = rng.gen_below(3) as f32;
        let v = rng.gen_range_f32(0.0, 32.0);
        let params = DriverParams {
            v0: rng.gen_range_f32(20.0, 38.0),
            t_headway: rng.gen_range_f32(0.9, 2.2),
            a_max: rng.gen_range_f32(1.0, 2.5),
            b_comf: rng.gen_range_f32(1.5, 3.5),
            s0: rng.gen_range_f32(1.5, 3.0),
            length: rng.gen_range_f32(4.0, 9.0),
            // no exit intent: these rollouts exercise the geometry
            // operand; the destination columns get their own coverage in
            // scenario_families.rs and the engine exit-column test
            ..DriverParams::default()
        };
        let _ = i;
        t.spawn(x, v, lane, params);
    }
    t
}

/// The bare IDM kernel (pallas, interpret-lowered) vs the rust port:
/// accelerations agree to f32 tolerance across random states.
#[test]
fn idm_kernel_matches_native_rust() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    for seed in 0..25u64 {
        let mut rng = Rng64::seed_from_u64(seed);
        let t = random_traffic(&mut rng, bucket, 0.7);
        let hlo = s.idm(bucket, &t.state, &t.params).unwrap();
        let native = idm_accel_all(&t);
        for i in 0..bucket {
            let (a, b) = (hlo[i], native[i]);
            let tol = 1e-3_f32.max(a.abs() * 1e-4);
            assert!(
                (a - b).abs() <= tol,
                "seed {seed} slot {i}: hlo {a} vs native {b}"
            );
        }
    }
}

/// The radar kernel vs the rust sensor model.
#[test]
fn radar_kernel_matches_native_rust() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    for seed in 0..25u64 {
        let mut rng = Rng64::seed_from_u64(seed ^ 0x5EED);
        let t = random_traffic(&mut rng, bucket, 0.7);
        let hlo = s.radar(bucket, &t.state).unwrap();
        for i in 0..bucket {
            let native = webots_hpc::webots::sensors::radar(&t, i, 150.0);
            assert!(
                (hlo[i * 2] - native.distance).abs() < 1e-3,
                "seed {seed} slot {i}: range {} vs {}",
                hlo[i * 2],
                native.distance
            );
            assert!(
                (hlo[i * 2 + 1] - native.closing_speed).abs() < 1e-3,
                "seed {seed} slot {i}: closing {} vs {}",
                hlo[i * 2 + 1],
                native.closing_speed
            );
        }
    }
}

/// Full step: HLO stepper vs native stepper over a multi-step rollout.
/// Trajectories track within tolerance (divergence grows with steps —
/// both integrate the same f32 math in different op orders).
#[test]
fn full_step_trajectories_track() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    for seed in 0..10u64 {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xD1CE);
        let t0 = random_traffic(&mut rng, bucket, 0.6);
        let mut t_hlo = t0.clone();
        let mut t_nat = t0.clone();
        let mut hlo = webots_hpc::runtime::HloStepper::new(s.clone(), bucket).unwrap();
        let mut nat = NativeIdmStepper::default();
        for step in 0..20 {
            let o1 = hlo.step(&mut t_hlo);
            let o2 = nat.step(&mut t_nat);
            assert_eq!(
                o1.n_active, o2.n_active,
                "seed {seed} step {step}: active count diverged"
            );
            for i in 0..bucket {
                assert!(
                    (t_hlo.x(i) - t_nat.x(i)).abs() < 0.5,
                    "seed {seed} step {step} slot {i}: x {} vs {}",
                    t_hlo.x(i),
                    t_nat.x(i)
                );
                assert!(
                    (t_hlo.v(i) - t_nat.v(i)).abs() < 0.5,
                    "seed {seed} step {step} slot {i}: v {} vs {}",
                    t_hlo.v(i),
                    t_nat.v(i)
                );
                assert_eq!(
                    t_hlo.lane(i),
                    t_nat.lane(i),
                    "seed {seed} step {step} slot {i}: lane diverged"
                );
            }
        }
    }
}

/// The persistent-session hot path is numerically identical to the
/// one-shot service API over a rollout (buffer reuse must not leak
/// state between steps).
#[test]
fn session_rollout_matches_oneshot_rollout() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    let mut rng = Rng64::seed_from_u64(0x5E55);
    let t0 = random_traffic(&mut rng, bucket, 0.6);
    let mut sess = s.session(bucket).unwrap();
    let mut state_sess = t0.state.clone();
    let mut state_solo = t0.state.clone();
    for step in 0..15 {
        let out = sess.step(&state_sess, &t0.params).unwrap();
        let solo = s.step(bucket, &state_solo, &t0.params).unwrap();
        assert_eq!(*out, solo, "session diverged from one-shot at step {step}");
        state_sess.copy_from_slice(&out.state);
        state_solo.copy_from_slice(&solo.state);
    }
}

/// Obs semantics agree: n_active from the artifact equals the rust count.
#[test]
fn obs_active_count_agrees() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    let mut rng = Rng64::seed_from_u64(99);
    let t = random_traffic(&mut rng, bucket, 0.5);
    let out = s.step(bucket, &t.state, &t.params).unwrap();
    assert_eq!(out.obs[0] as usize, t.active_count());
}

/// Manifest constants match the rust scenario (guards silent drift
/// between model.py and MergeScenario).
#[test]
fn manifest_constants_match_rust() {
    let Some(s) = service() else { return };
    s.manifest().validate_against_default_scenario().unwrap();
}

/// The vmapped batched step must be bit-equivalent to per-instance
/// single steps (the §Perf micro-batcher's correctness contract).
#[test]
fn batched_step_equals_singles() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    let b = s.manifest().batch;
    if b < 2 {
        eprintln!("no batched artifact; skipping");
        return;
    }
    let mut rng = Rng64::seed_from_u64(0xBA7C);
    let worlds: Vec<Traffic> = (0..b)
        .map(|i| random_traffic(&mut rng, bucket, 0.3 + 0.08 * i as f64))
        .collect();
    let mut states = Vec::new();
    let mut params = Vec::new();
    for w in &worlds {
        states.extend_from_slice(&w.state);
        params.extend_from_slice(&w.params);
    }
    let batched = s.step_batched(bucket, &states, &params).unwrap();
    assert_eq!(batched.len(), b);
    for (i, w) in worlds.iter().enumerate() {
        let single = s.step(bucket, &w.state, &w.params).unwrap();
        for (a, c) in single.state.iter().zip(batched[i].state.iter()) {
            assert!((a - c).abs() < 1e-4, "world {i}: state {a} vs {c}");
        }
        for (a, c) in single.obs.iter().zip(batched[i].obs.iter()) {
            assert!((a - c).abs() < 1e-4, "world {i}: obs {a} vs {c}");
        }
    }
}

/// The micro-batcher under concurrency: 8 threads stepping DIFFERENT
/// worlds must each get their own world's result (no cross-instance
/// contamination when requests coalesce).
#[test]
fn concurrent_micro_batching_keeps_worlds_separate() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    let mut rng = Rng64::seed_from_u64(0xC0DE);
    let worlds: Vec<Traffic> = (0..8)
        .map(|_| random_traffic(&mut rng, bucket, 0.5))
        .collect();
    // reference: serial singles
    let expect: Vec<_> = worlds
        .iter()
        .map(|w| s.step(bucket, &w.state, &w.params).unwrap())
        .collect();
    for _ in 0..5 {
        std::thread::scope(|scope| {
            let handles: Vec<_> = worlds
                .iter()
                .zip(expect.iter())
                .map(|(w, e)| {
                    let svc = s.clone();
                    scope.spawn(move || {
                        let out = svc.step(bucket, &w.state, &w.params).unwrap();
                        for (a, c) in out.state.iter().zip(e.state.iter()) {
                            assert!((a - c).abs() < 1e-4, "contaminated batch result");
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}

/// The PR 5 acceptance contract: a fused K-step rollout executable is
/// BIT-EXACT with K sequential step dispatches — final state and the
/// whole per-step obs trace — on every ladder rung, over all four
/// scenario-family geometries at fixed seeds, with exit-flagged traffic
/// so retirement happens inside the scan carry.  (Batched/coalesced
/// rollouts are tolerance-checked elsewhere; THIS claim is exact.)
#[test]
fn rollout_bit_exact_with_sequential_all_families() {
    use webots_hpc::scenario::{FamilyRegistry, UniformSampler};

    let Some(s) = service() else { return };
    if !s.manifest().rollouts_available() {
        eprintln!("skipping: artifacts predate schema 4 (no rollout entries)");
        return;
    }
    let ladder = s.manifest().rollout_steps.clone();
    let registry = FamilyRegistry::builtin().with_buckets(&s.manifest().buckets);
    for (fi, family) in ["highway-merge", "lane-drop", "ramp-weave", "ring-shockwave"]
        .iter()
        .enumerate()
    {
        let (_, cfg) = registry
            .materialize(family, &UniformSampler, 2, 0xF00D + fi as u64)
            .expect("builtin family compiles");
        let bucket = cfg.capacity;
        if !s.manifest().buckets.contains(&bucket) {
            eprintln!("note: {family} capacity {bucket} not lowered; skipping");
            continue;
        }
        let geom = cfg.geometry.geometry_vec();
        let mut rng = Rng64::seed_from_u64(0x2021 + fi as u64);
        let mut t = random_traffic(&mut rng, bucket, 0.6);
        // flag part of the fleet for a gore inside the road so exits
        // retire mid-chunk (schema-3 destination dynamics in the carry)
        let gore = cfg.geometry.road_end_m * 0.5;
        for i in 0..bucket {
            if t.is_active(i) && rng.gen_f64() < 0.3 {
                let (x, v, lane) = (t.x(i), t.v(i), t.lane(i));
                t.set_state_row(i, x, v, lane, true);
                t.set_params_row(i, DriverParams::default().with_exit(gore));
            }
        }
        for &k in &ladder {
            // sequential reference: K solo dispatches of the step entry
            let mut state = t.state.clone();
            let mut seq_obs: Vec<f32> = Vec::new();
            for _ in 0..k {
                let out = s.step_geom(bucket, &state, &t.params, geom).unwrap();
                state.copy_from_slice(&out.state);
                seq_obs.extend_from_slice(&out.obs);
            }
            // one fused dispatch of the rollout entry
            let roll = s.rollout_geom(bucket, k, &t.state, &t.params, geom).unwrap();
            assert_eq!(
                roll.state, state,
                "{family} K={k}: fused final state != sequential"
            );
            assert_eq!(
                roll.obs, seq_obs,
                "{family} K={k}: fused obs trace != sequential"
            );
        }
    }
}

/// End-to-end chunk scheduling on the HLO stepper: a chunk-scheduled
/// `SumoSim::run` over a real demand schedule produces the identical
/// per-step history and totals as step-by-step execution — departures,
/// queued insertions, exits and all.
#[test]
fn chunked_hlo_sim_equals_stepwise_hlo_sim() {
    use webots_hpc::runtime::HloStepper;
    use webots_hpc::sumo::{duarouter, steps_for, FlowFile, MergeScenario, SumoSim};

    let Some(s) = service() else { return };
    if !s.manifest().rollouts_available() {
        eprintln!("skipping: artifacts predate schema 4");
        return;
    }
    let bucket = s.manifest().buckets[1];
    let scenario = MergeScenario::default();
    let net = scenario.network();
    let flows = FlowFile::merge_sample(1200.0, 300.0, 40.0);
    let mk = |svc: &EngineService, chunk_limit: usize| {
        let routes = duarouter(&net, &flows, 11).unwrap();
        let stepper = HloStepper::new(svc.clone(), bucket).unwrap();
        let mut sim = SumoSim::new(scenario, bucket, routes, Box::new(stepper));
        sim.set_chunk_limit(chunk_limit);
        sim
    };
    let mut chunked = mk(&s, usize::MAX);
    let mut stepwise = mk(&s, 1);
    let h_chunked = chunked.run(60.0).unwrap();
    let mut h_stepwise = Vec::new();
    for _ in 0..steps_for(60.0, scenario.dt_s) {
        h_stepwise.push(stepwise.step());
    }
    assert_eq!(h_chunked, h_stepwise, "chunked history diverged");
    assert_eq!(chunked.traffic, stepwise.traffic);
    assert_eq!(chunked.total_flow, stepwise.total_flow);
    assert_eq!(chunked.total_exited, stepwise.total_exited);
    assert_eq!(chunked.total_spawned, stepwise.total_spawned);
}

/// The PR 10 acceptance contract: a device-resident whole-run dispatch
/// (departure table compiled in, insertion in-kernel) is BIT-EXACT with
/// PR-5 chunk-scheduled execution of the same demand — per-step history,
/// final traffic, and totals — over all four scenario families, with
/// departures coming due inside the fused window and exit-flagged
/// vehicles retiring mid-run.  The comparator gates the resident path
/// via `chunk_limit` (every run rung exceeds the rollout ladder), which
/// `chunked_hlo_sim_equals_stepwise_hlo_sim` has already proven equal
/// to step-by-step execution.
#[test]
fn whole_run_resident_bit_exact_with_chunked_all_families() {
    use webots_hpc::runtime::HloStepper;
    use webots_hpc::scenario::{FamilyRegistry, UniformSampler};
    use webots_hpc::sumo::{duarouter, RouteFile, SumoSim};

    let Some(s) = service() else { return };
    if !s.manifest().runs_available() {
        eprintln!("skipping: artifacts predate schema 5 (no run entries)");
        return;
    }
    // gating cap: admits every rollout rung, no run rung
    let chunk_cap = *s.manifest().rollout_steps.iter().max().unwrap_or(&1);
    assert!(
        s.manifest().run_steps.iter().all(|&t| t > chunk_cap),
        "run ladder must sit above the rollout ladder for this gate"
    );
    let registry = FamilyRegistry::builtin().with_buckets(&s.manifest().buckets);
    for (fi, family) in ["highway-merge", "lane-drop", "ramp-weave", "ring-shockwave"]
        .iter()
        .enumerate()
    {
        let (_, cfg) = registry
            .materialize(family, &UniformSampler, 2, 0xF00D + fi as u64)
            .expect("builtin family compiles");
        let bucket = cfg.capacity;
        if !s.manifest().buckets.contains(&bucket) {
            eprintln!("note: {family} capacity {bucket} not lowered; skipping");
            continue;
        }
        let mut routes = duarouter(&cfg.network, &cfg.flows, 77 + fi as u64).unwrap();
        // flag a slice of the demand for a gore early enough that exits
        // retire inside the 60 s window (ramp-weave also brings its own
        // exit-flagged off-flows)
        let gore = (cfg.geometry.road_end_m * 0.3).clamp(60.0, 250.0);
        let mut rng = Rng64::seed_from_u64(0x2021 + fi as u64);
        for d in &mut routes.departures {
            if rng.gen_f64() < 0.3 {
                d.params = d.params.with_exit(gore);
            }
        }
        let mk = |routes: &RouteFile, chunk_limit: usize| {
            let stepper = HloStepper::for_scenario(s.clone(), bucket, &cfg.geometry).unwrap();
            let mut sim = SumoSim::new(cfg.geometry, bucket, routes.clone(), Box::new(stepper));
            sim.set_chunk_limit(chunk_limit);
            sim
        };
        let mut fused = mk(&routes, usize::MAX);
        let mut chunked = mk(&routes, chunk_cap);
        let h_fused = fused.run(60.0).unwrap();
        let h_chunked = chunked.run(60.0).unwrap();
        // premise guards: the fast path engaged exactly once for fused,
        // never for the gated comparator, and the window really saw
        // departures and exits
        assert!(
            fused.resident_steps() > 0,
            "{family}: whole-run fast path did not engage"
        );
        assert_eq!(
            chunked.resident_steps(),
            0,
            "{family}: comparator must stay on the chunk scheduler"
        );
        assert!(fused.total_spawned > 1, "{family}: no mid-run departures");
        assert!(
            fused.total_exited > 0.0,
            "{family}: no exits inside the window"
        );
        assert_eq!(h_fused, h_chunked, "{family}: history diverged");
        assert_eq!(fused.traffic, chunked.traffic, "{family}: traffic diverged");
        assert_eq!(fused.total_flow, chunked.total_flow, "{family}: flow");
        assert_eq!(fused.total_exited, chunked.total_exited, "{family}: exited");
        assert_eq!(fused.total_spawned, chunked.total_spawned, "{family}: spawned");
    }
}
