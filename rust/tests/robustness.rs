//! Robustness acceptance tests — the evidence behind §5.1's "100%
//! simulation completion rate".
//!
//! The soak drives a whole supervised campaign through a seeded
//! transient-fault schedule (duarouter exits, display/port races,
//! in-run panics at ≥ 10% per site per attempt) and requires the
//! supervisor to converge to completion_rate == 1.0 with the retry
//! bill visible.  The kill/resume test abandons a campaign mid-flight
//! and requires the resumed ledger to produce the byte-identical
//! aggregate export with zero duplicate run_ids.
//!
//! `WEBOTS_HPC_SOAK_RUNS` scales the soak (default 16; check.sh runs
//! 32).  The fault schedule is a pure function of
//! `(plan seed, site, run seed, attempt)`, so every size is exactly
//! reproducible.

use std::time::Duration;

use webots_hpc::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use webots_hpc::display::DisplayRegistry;
use webots_hpc::pipeline::{
    launch_node_slots, run_supervised_campaign, supervise_instance, ChunkSteps, FaultInjection,
    FaultPlan, FaultSite, InstanceConfig, PhysicsEngine, RetryPolicy, SupervisedCampaignSpec,
    SupervisorSpec,
};
use webots_hpc::sumo::{steps_for, FlowFile, MergeScenario};
use webots_hpc::util::TempDir;
use webots_hpc::webots::nodes::sample_merge_world;
use webots_hpc::webots::WatchdogSpec;
use webots_hpc::Error;

/// Plan seed 99 over run seeds 1000.. converges within 10 attempts for
/// every soak size up to 128 (verified by exhaustive schedule replay) —
/// the soak proves the supervisor, not the dice.
const PLAN_SEED: u64 = 99;
const BASE_SEED: u64 = 1000;
const FAULT_RATE: f64 = 0.12;

fn soak_runs() -> u64 {
    std::env::var("WEBOTS_HPC_SOAK_RUNS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16)
}

fn fast_retry() -> RetryPolicy {
    RetryPolicy {
        max_attempts: 10,
        base_ms: 1,
        cap_ms: 5,
    }
}

fn soak_spec(name: &str, runs: u64, ledger_dir: std::path::PathBuf) -> SupervisedCampaignSpec {
    SupervisedCampaignSpec {
        name: name.into(),
        nodes: 1,
        slots_per_node: runs as u32,
        epochs: 1,
        horizon_s: 2.0,
        capacity: 64,
        seed: BASE_SEED,
        matrix: None,
        supervisor: SupervisorSpec {
            retry: fast_retry(),
            watchdog: WatchdogSpec::default(),
            degrade: false,
            fault_plan: Some(FaultPlan::transient_only(PLAN_SEED, FAULT_RATE)),
        },
        ledger_dir,
        retry_failed: false,
        stop_after_runs: None,
    }
}

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

fn instance_config(run_id: &str, port: u16, seed: u64) -> InstanceConfig {
    let scenario = MergeScenario::default();
    InstanceConfig {
        run_id: run_id.into(),
        node: 0,
        world: sample_merge_world(port),
        flows: FlowFile::merge_sample(1200.0, 300.0, 5.0),
        scenario,
        seed,
        capacity: 64,
        horizon_s: 5.0,
        max_steps: steps_for(5.0, scenario.dt_s) + 100,
        scenario_run: None,
        chunk_steps: ChunkSteps::Auto,
        faults: None,
        watchdog: WatchdogSpec::default(),
    }
}

fn exec_env() -> ExecEnv {
    ExecEnv::new(build_webots_hpc_image(BuildHost::PersonalComputer).unwrap())
}

/// The headline claim: a campaign soaked with ≥ 10% transient faults at
/// every retryable site still completes 100% of its runs, and the
/// accounting shows the retries that earned it.
#[test]
fn soak_transient_faults_complete_100_percent() {
    let runs = soak_runs();
    let dir = TempDir::new("webots-hpc-soak").unwrap();
    let spec = soak_spec("soak", runs, dir.path().to_path_buf());
    let outcome = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();

    assert!(!outcome.interrupted);
    let stats = outcome.result.robustness.expect("supervised accounting");
    assert_eq!(stats.runs, runs);
    assert_eq!(stats.completed, runs);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.completion_rate(), 1.0, "the §5.1 claim: {stats:?}");
    // the rate is ≥ 10% per site per attempt: a clean first-try sweep
    // would mean the injection never reached the launcher
    assert!(stats.retries > 0, "faults were injected: {stats:?}");
    assert_eq!(stats.attempts, stats.runs + stats.retries);
    assert_eq!(stats.degraded, 0, "no engine faults in the soak plan");

    assert_eq!(outcome.dataset.num_runs() as u64, runs);
    assert!(outcome.dataset.run_ids_unique(), "no duplicate run_ids");
    assert!(outcome.dataset.seeds_unique());
    // every retried run still landed exactly one CSV
    let csvs = std::fs::read_dir(dir.path().join("runs")).unwrap().count();
    assert_eq!(csvs as u64, runs);
}

/// Kill a campaign mid-flight, resume it from the same ledger dir, and
/// require the aggregate export to be byte-identical to an
/// uninterrupted campaign's — no duplicate run_ids, no holes, no
/// re-run drift (the fault schedule redraws identically on resume).
#[test]
fn killed_campaign_resumes_to_identical_aggregate() {
    let runs = 8u64;
    let interrupted_dir = TempDir::new("webots-hpc-resume").unwrap();
    let fresh_dir = TempDir::new("webots-hpc-fresh").unwrap();

    // session 1: killed after 3 launches
    let mut spec = soak_spec("camp", runs, interrupted_dir.path().to_path_buf());
    spec.stop_after_runs = Some(3);
    let killed = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    assert!(killed.interrupted);
    let s = killed.result.robustness.unwrap();
    assert_eq!(s.runs, 3);
    assert_eq!(s.resumed_skips, 0);

    // session 2: same ledger dir, no stop — finishes the remaining 5
    spec.stop_after_runs = None;
    let resumed = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    assert!(!resumed.interrupted);
    let s = resumed.result.robustness.unwrap();
    assert_eq!(s.runs, runs);
    assert_eq!(s.completed, runs);
    assert_eq!(s.resumed_skips, 3, "completed runs were skipped, not re-run");
    assert_eq!(resumed.reports.len(), 5, "only incomplete slots launched");

    // control: the same campaign, never killed
    let control_spec = soak_spec("camp", runs, fresh_dir.path().to_path_buf());
    let control = run_supervised_campaign(&control_spec, &PhysicsEngine::Native).unwrap();

    assert!(resumed.dataset.run_ids_unique());
    assert_eq!(
        resumed.dataset.to_ml_csv(),
        control.dataset.to_ml_csv(),
        "kill/resume changed the aggregate dataset"
    );
}

/// A crash can tear the ledger's final line mid-append; the resumed
/// session must truncate the fragment before appending, or its first
/// record glues onto the fragment and the *next* resume finds a
/// mid-file garbage line and refuses the whole ledger.
#[test]
fn torn_ledger_tail_survives_resume_and_a_second_resume() {
    use std::io::Write;
    let runs = 4u64;
    let dir = TempDir::new("webots-hpc-torn").unwrap();
    let mut spec = soak_spec("torn", runs, dir.path().to_path_buf());
    spec.supervisor.fault_plan = None;

    // session 1: killed after 2 launches, then the crash tears the tail
    spec.stop_after_runs = Some(2);
    run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    let ledger_path = dir.path().join("ledger.jsonl");
    {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&ledger_path)
            .unwrap();
        f.write_all(b"{\"run_id\":\"torn-e0[2]\",\"ep").unwrap();
    }

    // session 2: resumes past the torn tail and finishes the campaign
    spec.stop_after_runs = None;
    let resumed = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    let s = resumed.result.robustness.unwrap();
    assert_eq!(s.completed, runs);
    assert_eq!(s.resumed_skips, 2);

    // session 3: the ledger must still replay cleanly end to end
    let done = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    let s = done.result.robustness.unwrap();
    assert_eq!(s.completed, runs);
    assert_eq!(s.resumed_skips, runs, "every run settled, none re-ran");
    assert!(done.dataset.run_ids_unique());
}

/// Resuming a ledger dir under a different campaign shape must be
/// refused, not silently relabel seeds and grid coordinates in the
/// rebuilt aggregate.
#[test]
fn resume_refuses_a_changed_campaign_shape() {
    let dir = TempDir::new("webots-hpc-shape").unwrap();
    let mut spec = soak_spec("shape", 4, dir.path().to_path_buf());
    spec.supervisor.fault_plan = None;
    spec.stop_after_runs = Some(2);
    run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();

    spec.seed += 1; // same dir, different seed grid
    let err = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap_err();
    assert!(
        err.to_string().contains("different campaign shape"),
        "{err}"
    );
}

/// A run whose latest ledger state is a permanent failure stays
/// settled on resume — re-running a config error reproduces it
/// identically — unless `retry_failed` opts in after fixing the
/// inputs.
#[test]
fn permanent_failures_stay_settled_on_resume() {
    let runs = 2u64;
    let dir = TempDir::new("webots-hpc-perm").unwrap();
    let mut spec = soak_spec("perm", runs, dir.path().to_path_buf());
    spec.supervisor.fault_plan = None;

    // a prior session recorded slot 0 as permanently failed
    {
        let mut ledger =
            webots_hpc::pipeline::CampaignLedger::open(dir.path().join("ledger.jsonl")).unwrap();
        ledger
            .mark_failed("perm-e0[0]", 0, 0, 1, "permanent", "bad config")
            .unwrap();
    }

    let outcome = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    let s = outcome.result.robustness.unwrap();
    assert_eq!(s.runs, runs);
    assert_eq!(s.failed, 1, "the permanent failure stays failed");
    assert_eq!(s.completed, 1);
    assert_eq!(s.resumed_skips, 1);
    assert_eq!(outcome.reports.len(), 1, "only slot 1 launched");

    // opting in re-runs it; with the config "fixed" it completes
    spec.retry_failed = true;
    let outcome = run_supervised_campaign(&spec, &PhysicsEngine::Native).unwrap();
    let s = outcome.result.robustness.unwrap();
    assert_eq!(s.completed, runs);
    assert_eq!(s.failed, 0);
}

/// Regression for the node-wide abort: one slot panicking mid-run must
/// surface as that slot's `Error::Panic`, with every sibling still
/// joining and returning its own result.
#[test]
fn sibling_panic_is_one_failed_slot_not_a_node_abort() {
    let plan = FaultPlan::none(1).with_rate(FaultSite::InRunPanic, 1.0);
    let mut configs: Vec<InstanceConfig> = (0..3)
        .map(|i| instance_config(&format!("slot[{i}]"), free_port(), 50 + i))
        .collect();
    configs[1].faults = Some(FaultInjection { plan, attempt: 0 });

    let results = launch_node_slots(configs, &PhysicsEngine::Native);
    assert_eq!(results.len(), 3);
    assert!(results[0].is_ok(), "sibling 0 survived");
    assert!(results[2].is_ok(), "sibling 2 survived");
    match &results[1] {
        Err(Error::Panic(msg)) => assert!(msg.contains("injected"), "{msg}"),
        other => panic!("expected contained panic, got {other:?}"),
    }
}

/// Early-error and mid-run failures must release the Xvfb display lease
/// and the TraCI port — otherwise a retrying campaign starves the node
/// of displays/ports within a few faults.
#[test]
fn failed_launches_release_display_and_port() {
    let displays = DisplayRegistry::new();
    let env = exec_env();
    let once = RetryPolicy {
        max_attempts: 1,
        ..fast_retry()
    };

    // early error: TraCI accept fails after the display was acquired
    let spec = SupervisorSpec {
        retry: once,
        watchdog: WatchdogSpec::default(),
        degrade: false,
        fault_plan: Some(FaultPlan::none(1).with_rate(FaultSite::TraciAccept, 1.0)),
    };
    let port = free_port();
    let cfg = instance_config("leak-early", port, 7);
    let report = supervise_instance(&cfg, &displays, &env, &PhysicsEngine::Native, &spec);
    assert!(matches!(report.outcome, Err(Error::PortInUse(_))));
    assert_eq!(displays.in_use(), 0, "display lease released on early error");

    // mid-run panic: display AND a live TraCI server thread at unwind
    let spec = SupervisorSpec {
        fault_plan: Some(FaultPlan::none(1).with_rate(FaultSite::InRunPanic, 1.0)),
        ..spec
    };
    let port = free_port();
    let cfg = instance_config("leak-panic", port, 8);
    let report = supervise_instance(&cfg, &displays, &env, &PhysicsEngine::Native, &spec);
    assert!(matches!(report.outcome, Err(Error::Panic(_))));
    assert_eq!(displays.in_use(), 0, "display lease released on panic");
    // the server drop guard joined its thread, so the port is free again
    std::net::TcpListener::bind(("127.0.0.1", port))
        .unwrap_or_else(|e| panic!("port {port} still held after contained panic: {e}"));
}

/// The walltime deadline kills a run (setup time counts) and the kill
/// is classified transient and counted per attempt.
#[test]
fn walltime_watchdog_kills_and_counts() {
    let displays = DisplayRegistry::new();
    let env = exec_env();
    let spec = SupervisorSpec {
        retry: RetryPolicy {
            max_attempts: 2,
            ..fast_retry()
        },
        watchdog: WatchdogSpec {
            walltime: Some(Duration::ZERO),
            stall_window: None,
        },
        degrade: false,
        fault_plan: None,
    };
    let cfg = instance_config("walltime", free_port(), 9);
    let report = supervise_instance(&cfg, &displays, &env, &PhysicsEngine::Native, &spec);
    assert!(matches!(report.outcome, Err(Error::WalltimeExceeded(_))));
    assert_eq!(report.attempts, 2, "a walltime kill is retryable");
    assert_eq!(report.killed_walltime, 2);
    assert_eq!(displays.in_use(), 0, "killed attempts leak nothing");
}

/// A wedged back-end (injected mid-run stall) trips the stall window
/// and surfaces as `Error::Stalled` with the step count.
#[test]
fn stall_watchdog_kills_wedged_backend() {
    let displays = DisplayRegistry::new();
    let env = exec_env();
    let spec = SupervisorSpec {
        retry: RetryPolicy {
            max_attempts: 1,
            ..fast_retry()
        },
        watchdog: WatchdogSpec {
            walltime: None,
            stall_window: Some(Duration::from_millis(30)),
        },
        degrade: false,
        // stall_ms = 100 > the 30ms window: the burst comes back late
        fault_plan: Some(FaultPlan::none(1).with_rate(FaultSite::Stall, 1.0)),
    };
    let cfg = instance_config("stall", free_port(), 10);
    let report = supervise_instance(&cfg, &displays, &env, &PhysicsEngine::Native, &spec);
    match &report.outcome {
        Err(Error::Stalled(steps)) => assert!(*steps > 0, "stalled mid-run at step {steps}"),
        other => panic!("expected stall kill, got {other:?}"),
    }
    assert_eq!(report.killed_stall, 1);
    assert_eq!(displays.in_use(), 0);
}

/// Graceful degradation: a PJRT dispatch failure on the HLO path
/// relaunches on the native stepper and the completed dataset carries
/// the `degraded` provenance flag.  No-ops with a note when `make
/// artifacts` hasn't run (same convention as the runtime tests).
#[test]
fn engine_failure_degrades_to_native_with_provenance() {
    let service = match webots_hpc::runtime::EngineService::auto() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping degradation test: {e}");
            return;
        }
    };
    let displays = DisplayRegistry::new();
    let env = exec_env();
    let spec = SupervisorSpec {
        retry: fast_retry(),
        watchdog: WatchdogSpec::default(),
        degrade: true,
        fault_plan: Some(FaultPlan::none(1).with_rate(FaultSite::PjrtDispatch, 1.0)),
    };
    let cfg = instance_config("degrade", free_port(), 11);
    let report = supervise_instance(
        &cfg,
        &displays,
        &env,
        &PhysicsEngine::Hlo(service.clone()),
        &spec,
    );
    let r = report.outcome.expect("completed on the native fallback");
    assert!(report.degraded);
    assert!(r.dataset.degraded, "dataset carries the fallback provenance");
    assert_eq!(report.attempts, 2, "one engine failure, one native relaunch");
    assert_eq!(report.failures.len(), 1);
    assert_eq!(report.failures[0].backoff_ms, 0, "degradation doesn't wait");
    service.shutdown();
}
