//! Table 4.1 as executable tests: every development challenge the paper
//! hit is reproduced mechanically, then its published solution is shown
//! to work.

use webots_hpc::container::{
    build_webots_hpc_image, modify_sif_on_cluster, singularity_build, BuildHost, DockerImage,
    ExecEnv,
};
use webots_hpc::display::{DisplayRegistry, SshSession, X11Forward, XvfbRun};
use webots_hpc::pipeline::{propagate_copies, PortAllocator};
use webots_hpc::sumo::{duarouter, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
use webots_hpc::traci::TraciServer;
use webots_hpc::webots::nodes::sample_merge_world;
use webots_hpc::Error;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

fn test_sim(seed: u64) -> SumoSim {
    let scenario = MergeScenario::default();
    let routes = duarouter(
        &scenario.network(),
        &FlowFile::merge_sample(1200.0, 300.0, 30.0),
        seed,
    )
    .unwrap();
    SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()))
}

/// Row 2-4: docker→singularity conversion; SIF immutability; the
/// missing-pip dead end; the working publication loop.
#[test]
fn challenge_container_conversion_loop() {
    // the dead end: modify on the cluster
    let mut sif = singularity_build(&DockerImage::official_webots(), false);
    assert!(matches!(
        modify_sif_on_cluster(&mut sif, "numpy"),
        Err(Error::ImmutableImage(_))
    ));
    // the dead end: bootstrap pip on the cluster
    assert!(matches!(
        build_webots_hpc_image(BuildHost::Cluster),
        Err(Error::PermissionDenied(_))
    ));
    // the published solution: admin host, then convert
    let sif = build_webots_hpc_image(BuildHost::PersonalComputer).unwrap();
    assert!(sif.has_python_package("numpy"));
    assert!(sif.has_python_package("pandas"));
}

/// Row 5: GUI needs ssh -X.
#[test]
fn challenge_gui_needs_x11_forwarding() {
    let reg = DisplayRegistry::new();
    let no_x = SshSession::connect("user", "host", false);
    assert!(X11Forward::open(&no_x, &reg).is_err());
    let with_x = SshSession::connect("user", "host", true);
    assert!(X11Forward::open(&with_x, &reg).is_ok());
}

/// Row 6: headless mode under Xvfb; `-a` required for n > 1.
#[test]
fn challenge_headless_xvfb_dash_a() {
    let reg = DisplayRegistry::new();
    let fixed = XvfbRun::default();
    let _one = fixed.acquire(&reg).unwrap();
    assert!(matches!(
        fixed.acquire(&reg),
        Err(Error::DisplayInUse(99))
    ));
    // the fix
    let auto = XvfbRun::auto();
    let two = auto.acquire(&reg).unwrap();
    assert_eq!(two.number, 100);
}

/// Row 8: the duplicate-port issue, on real sockets, and the paper's fix
/// (base 8873, step 7) making 8 parallel servers coexist.
#[test]
fn challenge_duplicate_port_and_fix() {
    // the crash
    let port = free_port();
    let s1 = TraciServer::spawn(port, test_sim(1)).unwrap();
    assert!(matches!(
        TraciServer::spawn(port, test_sim(2)),
        Err(Error::PortInUse(p)) if p == port
    ));
    let mut c = webots_hpc::traci::TraciClient::connect(port).unwrap();
    c.close().unwrap();
    s1.join().unwrap();

    // the fix: 8 distinct ports via the world-copy propagation
    let base = free_port();
    let root = sample_merge_world(base);
    let copies = propagate_copies(&root, 8, &PortAllocator::new(base, 7)).unwrap();
    let servers: Vec<TraciServer> = copies
        .iter()
        .map(|c| TraciServer::spawn(c.port, test_sim(c.index as u64)).unwrap())
        .collect();
    for (i, s) in servers.into_iter().enumerate() {
        let mut c = webots_hpc::traci::TraciClient::connect(base + 7 * i as u16).unwrap();
        c.sim_step().unwrap();
        c.close().unwrap();
        s.join().unwrap();
    }
}

/// Row 9: distribution across nodes — PBS packs 48 instances 8-per-node.
#[test]
fn challenge_distribution_across_nodes() {
    use webots_hpc::cluster::{Cluster, ClusterQueue, NodeSpec, QueueSpec};
    use webots_hpc::metrics::FixedWorkload;
    use webots_hpc::pbs::{ArrayRange, Job, JobId, ResourceRequest, Scheduler, SchedulerConfig};

    let cluster = Cluster::uniform("t", 6, NodeSpec::dice_r740());
    let queue = ClusterQueue::new(QueueSpec::dicelab(6));
    let mut s = Scheduler::new(cluster, queue, SchedulerConfig::default());
    s.submit(
        Job::new(JobId(0), "webots", ResourceRequest::experiment_15min())
            .with_array(ArrayRange::new(1, 48).unwrap()),
        Box::new(FixedWorkload::minutes(10)),
    )
    .unwrap();
    assert_eq!(s.occupancy(), vec![8; 6]);
}

/// Row 1 epilogue: the chosen method actually runs a simulation inside
/// the container env (binary resolution through the SIF).
#[test]
fn challenge_best_method_runs_webots() {
    let sif = build_webots_hpc_image(BuildHost::PersonalComputer).unwrap();
    let env = ExecEnv::new(sif).bind("/tmp/job", "/tmp/job");
    env.exec("webots", &["--batch", "--mode=realtime", "SIM_0.wbt"])
        .unwrap();
    env.exec("duarouter", &["--randomize-flows", "true"]).unwrap();
    env.exec("xvfb-run", &["-a", "webots"]).unwrap();
    // audio (row 7) stays unresolved, as in the paper: no audio binary
    assert!(env.exec("pulseaudio", &[]).is_err());
}
