//! Cross-module integration tests: the full pipeline composed end to
//! end, from PBS script text to aggregated output datasets.

use webots_hpc::cluster::{Cluster, ClusterQueue, NodeSpec, QueueSpec};
use webots_hpc::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use webots_hpc::display::DisplayRegistry;
use webots_hpc::metrics::{CostModel, SimWorkload};
use webots_hpc::output::CampaignDataset;
use webots_hpc::pbs::script::{appendix_b_script, PbsScript};
use webots_hpc::pbs::{JobId, JobState, Scheduler, SchedulerConfig};
use webots_hpc::pipeline::{
    launch_instance, launch_node_slots, pick_walltime, propagate_copies, run_cluster_campaign,
    CampaignSpec, ChunkSteps, InstanceConfig, PhysicsEngine, PortAllocator, WalltimePolicy,
};
use webots_hpc::simclock::SimDuration;
use webots_hpc::sumo::{FlowFile, MergeScenario};
use webots_hpc::webots::nodes::sample_merge_world;

fn free_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .unwrap()
        .local_addr()
        .unwrap()
        .port()
}

/// The paper's headline reliability claim, at full scale: a 12-hour
/// virtual campaign completes 2304/2304 runs.
#[test]
fn campaign_completion_100_percent() {
    let r = run_cluster_campaign(&CampaignSpec::paper_cluster()).unwrap();
    assert_eq!(r.stats.submitted, 2304);
    assert_eq!(r.stats.completed, 2304);
    assert_eq!(r.stats.killed_walltime, 0);
    assert_eq!(r.stats.completion_rate(), 1.0);
}

/// Appendix-B script → parse → submit → schedule → account: the
/// user-visible flow of the whole pipeline.
#[test]
fn appendix_b_script_schedules_8_per_node() {
    let script = PbsScript::parse(&appendix_b_script()).unwrap();
    let cluster = Cluster::uniform("palmetto", 6, NodeSpec::dice_r740());
    let queue = ClusterQueue::new(QueueSpec::dicelab(6));
    let mut sched = Scheduler::new(cluster, queue, SchedulerConfig::default());
    let job = script.to_job(JobId(0));
    sched
        .submit(
            job,
            Box::new(SimWorkload::new(CostModel::paper_merge_sim(), 7)),
        )
        .unwrap();
    assert_eq!(sched.occupancy(), vec![8; 6]);
    sched.run_to_completion();
    assert_eq!(sched.stats().completed, 48);
    // every record must hold plausible usage numbers
    for rec in sched.records() {
        assert!(rec.state == JobState::Completed);
        assert!(rec.usage.walltime.as_secs_f64() > 100.0);
        assert!(rec.usage.max_ram_gb > 1.0);
    }
}

/// The walltime the policy picks for the paper's slot is exactly the
/// paper's experimental walltime, and the cost-model run fits inside it.
#[test]
fn picked_walltime_admits_the_run() {
    let cost = CostModel::paper_merge_sim();
    let w = pick_walltime(&cost, 5, &WalltimePolicy::default());
    assert_eq!(w.as_minutes(), 15);
    assert!(cost.walltime_s(5) < w.as_secs_f64());
}

/// Physics-fidelity instance through the container + display + TraCI +
/// Webots stack, native engine.
#[test]
fn single_instance_end_to_end_native() {
    let world = sample_merge_world(free_port());
    let env = ExecEnv::new(build_webots_hpc_image(BuildHost::PersonalComputer).unwrap())
        .bind("/tmp", "/tmp");
    let displays = DisplayRegistry::new();
    let cfg = InstanceConfig {
        run_id: "it[0]".into(),
        node: 0,
        world,
        flows: FlowFile::merge_sample(1200.0, 300.0, 20.0),
        scenario: MergeScenario::default(),
        seed: 3,
        capacity: 64,
        horizon_s: 20.0,
        max_steps: 500,
        scenario_run: None,
        chunk_steps: ChunkSteps::Auto,
        faults: None,
        watchdog: Default::default(),
    };
    let r = launch_instance(&cfg, &displays, &env, &PhysicsEngine::Native).unwrap();
    assert_eq!(r.steps, 200);
    assert!(r.dataset.total_spawned > 0);
}

/// Same thing on the AOT JAX/Pallas artifact (skipped when artifacts are
/// missing), with several instances in parallel sharing one PJRT
/// engine service.
#[test]
fn parallel_instances_end_to_end_hlo() {
    let service = match webots_hpc::runtime::EngineService::auto() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let base = free_port();
    let root = sample_merge_world(base);
    let copies = propagate_copies(&root, 4, &PortAllocator::new(base, 7)).unwrap();
    let configs: Vec<InstanceConfig> = copies
        .into_iter()
        .map(|c| InstanceConfig {
            run_id: format!("it[{}]", c.index),
            node: 0,
            world: c.world,
            flows: FlowFile::merge_sample(1200.0, 300.0, 10.0),
            scenario: MergeScenario::default(),
            seed: 100 + c.index as u64,
            capacity: 64,
            horizon_s: 10.0,
            max_steps: 300,
            scenario_run: None,
            chunk_steps: ChunkSteps::Auto,
            faults: None,
            watchdog: Default::default(),
        })
        .collect();
    let results = launch_node_slots(configs, &PhysicsEngine::Hlo(service));
    let mut ds = CampaignDataset::new();
    for r in results {
        ds.add(r.unwrap().dataset);
    }
    assert_eq!(ds.num_runs(), 4);
    assert!(ds.seeds_unique());
    assert!(ds.total_rows() >= 4 * 100);
}

/// §5.1's scaling claim: doubling nodes doubles completed runs.
#[test]
fn throughput_scales_linearly_with_nodes() {
    let mut spec = CampaignSpec::paper_cluster();
    spec.duration = SimDuration::from_hours(3);
    let six = run_cluster_campaign(&spec).unwrap().total_completed();
    spec.nodes = 12;
    let twelve = run_cluster_campaign(&spec).unwrap().total_completed();
    assert_eq!(twelve, 2 * six);
}

/// Campaign submission honors queue caps end to end.
#[test]
fn queue_walltime_cap_rejects_bad_campaign() {
    let mut spec = CampaignSpec::paper_cluster();
    spec.walltime = SimDuration::from_hours(100);
    spec.duration = SimDuration::from_hours(200);
    assert!(run_cluster_campaign(&spec).is_err());
}

/// The world-copy tree written to disk round-trips through the pipeline:
/// copies load back with their unique ports and boot real instances.
#[test]
fn copy_tree_boots_from_disk() {
    let tmp = webots_hpc::util::TempDir::new("it-copytree").unwrap();
    let base = free_port();
    let root = sample_merge_world(base);
    let copies = propagate_copies(&root, 2, &PortAllocator::new(base, 7)).unwrap();
    let scenario = MergeScenario::default();
    let flows = FlowFile::merge_sample(1200.0, 300.0, 10.0);
    webots_hpc::pipeline::write_copy_tree(tmp.path(), &copies, &scenario.network(), &flows)
        .unwrap();

    // reload copy 1 from disk and run it
    let world = webots_hpc::webots::World::load(&tmp.path().join("SIM_1.wbt")).unwrap();
    let env = ExecEnv::new(build_webots_hpc_image(BuildHost::PersonalComputer).unwrap());
    let displays = DisplayRegistry::new();
    let cfg = InstanceConfig {
        run_id: "disk[1]".into(),
        node: 0,
        world,
        flows,
        scenario,
        seed: 5,
        capacity: 64,
        horizon_s: 5.0,
        max_steps: 100,
        scenario_run: None,
        chunk_steps: ChunkSteps::Auto,
        faults: None,
        watchdog: Default::default(),
    };
    let r = launch_instance(&cfg, &displays, &env, &PhysicsEngine::Native).unwrap();
    assert_eq!(r.port, base + 7, "copy 1 runs on base+7");
}
