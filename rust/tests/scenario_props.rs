//! Property tests for the scenario samplers (ISSUE 2 satellite):
//! `(space, seed, index) → point` must be pure and reproducible across
//! calls, sampler instances, and evaluation order — that is the whole
//! coordination-free contract a PBS array node relies on — and the
//! Latin-hypercube sampler must cover every stratum of every continuous
//! axis exactly once.

use webots_hpc::scenario::{
    Axis, AxisKind, AxisValue, FamilyRegistry, GridSampler, LatinHypercubeSampler, Sampler,
    SamplerKind, ScenarioSpace, UniformSampler,
};

/// A synthetic space exercising all three axis kinds.
fn mixed_space() -> ScenarioSpace {
    ScenarioSpace::new(
        "mixed",
        vec![
            Axis::continuous("demand", 600.0, 2400.0),
            Axis::continuous("penetration", 0.0, 1.0),
            Axis::integer("lanes", 1, 4),
            Axis::choice("profile", &["calm", "normal", "aggressive"]),
        ],
    )
}

fn samplers() -> Vec<Box<dyn Sampler>> {
    vec![
        Box::new(GridSampler { points_per_axis: 4 }),
        Box::new(UniformSampler),
        Box::new(LatinHypercubeSampler { strata: 16 }),
    ]
}

#[test]
fn identical_coordinates_reproduce_identical_points() {
    let space = mixed_space();
    for sampler in samplers() {
        for seed in [0u64, 7, 2021, u64::MAX] {
            for index in [0u64, 1, 5, 15, 1000] {
                let a = sampler.sample(&space, seed, index);
                let b = sampler.sample(&space, seed, index);
                assert_eq!(a, b, "{} must be pure", sampler.name());
                assert_eq!(a.family.as_str(), "mixed");
                assert_eq!(a.index, index);
                assert_eq!(a.seed, seed);
            }
        }
    }
}

#[test]
fn reproducible_across_fresh_instances_and_order() {
    let space = mixed_space();
    // a "node" that only materializes index 13 must see exactly what a
    // node enumerating 0..16 sees at 13 — no hidden sampler state
    let full: Vec<_> = (0..16)
        .map(|i| LatinHypercubeSampler { strata: 16 }.sample(&space, 42, i))
        .collect();
    let lone = LatinHypercubeSampler { strata: 16 }.sample(&space, 42, 13);
    assert_eq!(full[13], lone);

    let u_full: Vec<_> = (0..16).map(|i| UniformSampler.sample(&space, 42, i)).collect();
    assert_eq!(u_full[13], UniformSampler.sample(&space, 42, 13));
}

#[test]
fn builtin_family_spaces_sample_cleanly() {
    let registry = FamilyRegistry::builtin();
    for id in registry.ids() {
        let space = registry.get(&id).unwrap().space();
        for sampler in samplers() {
            for index in 0..8 {
                let p = sampler.sample(&space, 3, index);
                assert_eq!(p.values.len(), space.axes.len(), "{id}/{}", sampler.name());
                // every value lies inside its axis
                for (axis, value) in space.axes.iter().zip(p.values.iter()) {
                    match (&axis.kind, value) {
                        (AxisKind::Continuous { lo, hi }, AxisValue::Num(v)) => {
                            assert!(*v >= *lo && *v <= *hi, "{id}.{}={v}", axis.name)
                        }
                        (AxisKind::Integer { lo, hi }, AxisValue::Int(v)) => {
                            assert!(v >= lo && v <= hi, "{id}.{}={v}", axis.name)
                        }
                        (AxisKind::Choice { options }, AxisValue::Tag(t)) => {
                            assert!(options.contains(t), "{id}.{}={t}", axis.name)
                        }
                        (kind, value) => {
                            panic!("{id}.{}: kind {kind:?} produced {value:?}", axis.name)
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn lhs_covers_every_stratum_exactly_once() {
    let space = mixed_space();
    for n in [4usize, 16, 48] {
        let sampler = LatinHypercubeSampler { strata: n };
        for seed in [1u64, 9, 31337] {
            // for every continuous axis: recover each sample's stratum
            // and require a perfect 0..n permutation
            for (ai, axis) in space.axes.iter().enumerate() {
                let AxisKind::Continuous { lo, hi } = axis.kind else {
                    continue;
                };
                let mut strata: Vec<usize> = (0..n as u64)
                    .map(|i| {
                        let p = sampler.sample(&space, seed, i);
                        match p.values[ai] {
                            AxisValue::Num(v) => ((v - lo) / (hi - lo) * n as f64) as usize,
                            ref other => panic!("{other:?}"),
                        }
                    })
                    .collect();
                strata.sort_unstable();
                assert_eq!(
                    strata,
                    (0..n).collect::<Vec<_>>(),
                    "axis '{}' n={n} seed={seed}",
                    axis.name
                );
            }
        }
    }
}

#[test]
fn lhs_axes_use_distinct_permutations() {
    // the per-axis permutations must not be the same permutation —
    // otherwise the sweep degenerates to a diagonal
    let space = mixed_space();
    let n = 16usize;
    let sampler = LatinHypercubeSampler { strata: n };
    let strata_of = |ai: usize| -> Vec<usize> {
        (0..n as u64)
            .map(|i| match sampler.sample(&space, 5, i).values[ai] {
                AxisValue::Num(v) => {
                    let (lo, hi) = match space.axes[ai].kind {
                        AxisKind::Continuous { lo, hi } => (lo, hi),
                        _ => unreachable!(),
                    };
                    ((v - lo) / (hi - lo) * n as f64) as usize
                }
                ref other => panic!("{other:?}"),
            })
            .collect()
    };
    assert_ne!(strata_of(0), strata_of(1));
}

#[test]
fn different_seeds_and_indices_vary_the_points() {
    let space = mixed_space();
    for sampler in [
        Box::new(UniformSampler) as Box<dyn Sampler>,
        Box::new(LatinHypercubeSampler { strata: 32 }),
    ] {
        let a = sampler.sample(&space, 1, 0);
        let b = sampler.sample(&space, 2, 0);
        assert_ne!(a.values, b.values, "{} seed sensitivity", sampler.name());
        let c = sampler.sample(&space, 1, 1);
        assert_ne!(a.values, c.values, "{} index sensitivity", sampler.name());
    }
}

#[test]
fn grid_enumerates_the_full_lattice_then_wraps() {
    let space = ScenarioSpace::new(
        "g",
        vec![
            Axis::continuous("x", 0.0, 1.0),
            Axis::integer("k", 0, 2),
            Axis::choice("c", &["a", "b"]),
        ],
    );
    let g = GridSampler { points_per_axis: 3 };
    let total = g.total_points(&space);
    assert_eq!(total, 3 * 3 * 2);
    let mut seen = std::collections::BTreeSet::new();
    for i in 0..total {
        let p = g.sample(&space, 0, i);
        let key: Vec<String> = p.values.iter().map(|v| v.render()).collect();
        assert!(seen.insert(key.join("|")), "lattice point {i} repeated");
    }
    assert_eq!(seen.len() as u64, total);
    assert_eq!(g.sample(&space, 0, total).values, g.sample(&space, 0, 0).values);
}

#[test]
fn sampler_kind_matches_concrete_samplers() {
    let space = mixed_space();
    assert_eq!(
        SamplerKind::Lhs { strata: 8 }.sample(&space, 4, 2),
        LatinHypercubeSampler { strata: 8 }.sample(&space, 4, 2)
    );
    assert_eq!(
        SamplerKind::Uniform.sample(&space, 4, 2),
        UniformSampler.sample(&space, 4, 2)
    );
    assert_eq!(
        SamplerKind::Grid { points_per_axis: 5 }.sample(&space, 4, 2),
        GridSampler { points_per_axis: 5 }.sample(&space, 4, 2)
    );
}
