//! Property-based tests on scheduler/pipeline invariants.
//!
//! The vendored offline crate set has no proptest, so cases are generated
//! with the crate's deterministic [`Rng64`] across many seeds — same
//! spirit: random job mixes, asserted invariants, reproducible failures
//! (the seed is in the panic message).

use webots_hpc::cluster::{Cluster, ClusterQueue, NodeSpec, QueueSpec, ResourceDemand};
use webots_hpc::metrics::{CostModel, FixedWorkload, SimWorkload};
use webots_hpc::pbs::{
    ArrayRange, Job, JobId, JobState, PackingPolicy, ResourceRequest, Scheduler, SchedulerConfig,
};
use webots_hpc::pipeline::PortAllocator;
use webots_hpc::simclock::{SimDuration, SimInstant};
use webots_hpc::util::Rng64;

const CASES: u64 = 60;

fn random_request(rng: &mut Rng64) -> ResourceRequest {
    ResourceRequest {
        select: 1,
        chunk: ResourceDemand {
            ncpus: 1 + rng.gen_below(12) as u32,
            mem_gb: 1.0 + rng.gen_f64() * 120.0,
            scratch_gb: 0.0,
            ngpus: 0,
        },
        interconnect: None,
        walltime: SimDuration::from_minutes(5 + rng.gen_below(30)),
    }
}

fn random_scheduler(rng: &mut Rng64) -> Scheduler {
    let nodes = 2 + rng.gen_below(6) as usize;
    let policy = if rng.gen_below(2) == 0 {
        PackingPolicy::FirstFit
    } else {
        PackingPolicy::RoundRobin
    };
    let backfill = rng.gen_below(2) == 0;
    Scheduler::new(
        Cluster::uniform("prop", nodes, NodeSpec::dice_r740()),
        ClusterQueue::new(QueueSpec::dicelab(nodes)),
        SchedulerConfig { policy, backfill },
    )
}

/// Invariant: every submitted subjob reaches a terminal state, and
/// completed + killed == submitted (no lost or duplicated work).
#[test]
fn prop_conservation_of_jobs() {
    for seed in 0..CASES {
        let mut rng = Rng64::seed_from_u64(seed);
        let mut s = random_scheduler(&mut rng);
        let mut expected = 0u64;
        for _ in 0..(1 + rng.gen_below(5)) {
            let req = random_request(&mut rng);
            let n = 1 + rng.gen_below(40) as u32;
            expected += n as u64;
            let runtime = 1 + rng.gen_below(25);
            s.submit(
                Job::new(JobId(0), "p", req).with_array(ArrayRange::new(1, n).unwrap()),
                Box::new(FixedWorkload::minutes(runtime)),
            )
            .unwrap();
        }
        s.run_to_completion();
        let st = s.stats();
        assert_eq!(
            st.completed + st.killed_walltime + st.failed,
            expected,
            "seed {seed}: conservation violated"
        );
    }
}

/// Invariant: the cluster is never oversubscribed — after completion all
/// resources are free, and during the run `allocate` would have panicked
/// on oversubscription (it returns Err and the scheduler only books
/// candidates that fit).
#[test]
fn prop_all_resources_released() {
    for seed in 0..CASES {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xABCD);
        let mut s = random_scheduler(&mut rng);
        let free_before: u32 = s.cluster().total_free_cores();
        for _ in 0..(1 + rng.gen_below(4)) {
            let n = 1 + rng.gen_below(60) as u32;
            s.submit(
                Job::new(JobId(0), "p", random_request(&mut rng))
                    .with_array(ArrayRange::new(1, n).unwrap()),
                Box::new(FixedWorkload::minutes(1 + rng.gen_below(20))),
            )
            .unwrap();
        }
        s.run_to_completion();
        assert_eq!(
            s.cluster().total_free_cores(),
            free_before,
            "seed {seed}: leaked cores"
        );
        assert_eq!(s.occupancy().iter().sum::<usize>(), 0, "seed {seed}");
    }
}

/// Invariant: determinism — the same seed gives bit-identical completion
/// timelines.
#[test]
fn prop_deterministic_replay() {
    for seed in 0..CASES / 2 {
        let build = |seed: u64| {
            let mut rng = Rng64::seed_from_u64(seed);
            let mut s = random_scheduler(&mut rng);
            for _ in 0..3 {
                let n = 1 + rng.gen_below(30) as u32;
                s.submit(
                    Job::new(JobId(0), "p", random_request(&mut rng))
                        .with_array(ArrayRange::new(1, n).unwrap()),
                    Box::new(SimWorkload::new(CostModel::paper_merge_sim(), seed)),
                )
                .unwrap();
            }
            s.run_to_completion();
            s.completions().to_vec()
        };
        assert_eq!(build(seed), build(seed), "seed {seed}: non-deterministic");
    }
}

/// Invariant: walltime enforcement — no completed run exceeded its
/// walltime, every killed run hit exactly its walltime.
#[test]
fn prop_walltime_enforced() {
    for seed in 0..CASES {
        let mut rng = Rng64::seed_from_u64(seed ^ 0x5A5A);
        let mut s = random_scheduler(&mut rng);
        let walltime = SimDuration::from_minutes(5 + rng.gen_below(20));
        let runtime = SimDuration::from_minutes(1 + rng.gen_below(40));
        let req = ResourceRequest {
            walltime,
            ..random_request(&mut rng)
        };
        let n = 1 + rng.gen_below(20) as u32;
        s.submit(
            Job::new(JobId(0), "p", req).with_array(ArrayRange::new(1, n).unwrap()),
            Box::new(FixedWorkload {
                duration: runtime,
                cpu_time_s: runtime.as_secs_f64(),
                ram_gb: 2.0,
            }),
        )
        .unwrap();
        s.run_to_completion();
        for rec in s.records() {
            match rec.state {
                JobState::Completed => assert!(
                    rec.usage.walltime <= walltime,
                    "seed {seed}: completed past walltime"
                ),
                JobState::KilledWalltime => assert_eq!(
                    rec.usage.walltime, walltime,
                    "seed {seed}: kill not at walltime"
                ),
                other => panic!("seed {seed}: unexpected terminal state {other:?}"),
            }
        }
        let st = s.stats();
        if runtime <= walltime {
            assert_eq!(st.killed_walltime, 0, "seed {seed}");
        } else {
            assert_eq!(st.completed, 0, "seed {seed}");
        }
    }
}

/// Invariant: identical-chunk saturating arrays distribute perfectly
/// evenly regardless of policy (the §5.2 claim generalized).
#[test]
fn prop_even_distribution_when_saturating() {
    for seed in 0..CASES {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xFEED);
        let nodes = 2 + rng.gen_below(8) as usize;
        let slots_wanted = 1 + rng.gen_below(8) as u32;
        let cores_per = (40 / slots_wanted).max(1);
        // actual per-node capacity at this chunk size (e.g. asking for 7
        // slots of 5 cores still fits 8 per 40-core node)
        let slots = 40 / cores_per;
        let mut s = Scheduler::new(
            Cluster::uniform("prop", nodes, NodeSpec::dice_r740()),
            ClusterQueue::new(QueueSpec::dicelab(nodes)),
            SchedulerConfig::default(),
        );
        let req = ResourceRequest {
            select: 1,
            chunk: ResourceDemand {
                ncpus: cores_per,
                mem_gb: 1.0,
                scratch_gb: 0.0,
                ngpus: 0,
            },
            interconnect: None,
            walltime: SimDuration::from_minutes(15),
        };
        let n = nodes as u32 * slots;
        s.submit(
            Job::new(JobId(0), "p", req).with_array(ArrayRange::new(1, n).unwrap()),
            Box::new(FixedWorkload::minutes(10)),
        )
        .unwrap();
        let occ = s.occupancy();
        // 40/slots may leave a remainder core; every node still gets
        // exactly `slots` because chunks are identical
        assert!(
            occ.iter().all(|&o| o == slots as usize),
            "seed {seed}: occupancy {occ:?} != {slots}/node"
        );
    }
}

/// Invariant: port plans are collision-free for every step >= 1 and
/// always collide for step 0.
#[test]
fn prop_port_plans() {
    for seed in 0..CASES {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xC0FFEE);
        let base = 1024 + rng.gen_below(40_000) as u16;
        let step = rng.gen_below(12) as u16;
        let n = 1 + rng.gen_below(16) as u16;
        let plan = PortAllocator::new(base, step).plan(n);
        if step == 0 && n > 1 {
            assert!(plan.is_err(), "seed {seed}: step 0 must collide");
        } else if let Ok(ports) = plan {
            let mut sorted = ports.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), n as usize, "seed {seed}: duplicate ports");
        }
        // overflow cases return Err, never panic — exercised implicitly
    }
}

/// Invariant: the completion timeline is monotone in time and never
/// exceeds the submitted count.
#[test]
fn prop_timeline_monotone() {
    for seed in 0..CASES / 2 {
        let mut rng = Rng64::seed_from_u64(seed ^ 0xBEEF);
        let mut s = random_scheduler(&mut rng);
        let n = 10 + rng.gen_below(50) as u32;
        s.submit(
            Job::new(JobId(0), "p", random_request(&mut rng))
                .with_array(ArrayRange::new(1, n).unwrap()),
            Box::new(SimWorkload::new(CostModel::paper_merge_sim(), seed)),
        )
        .unwrap();
        s.run_to_completion();
        let mut last = 0;
        for minutes in (0..120).step_by(5) {
            let c = s.completed_at(SimInstant::ZERO + SimDuration::from_minutes(minutes));
            assert!(c >= last, "seed {seed}: timeline decreased");
            assert!(c <= n as u64, "seed {seed}: more completions than jobs");
            last = c;
        }
    }
}
