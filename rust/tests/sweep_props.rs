//! Property tests: the sorted-sweep neighbor index is **bit-exact**
//! with the O(N²) reference scans across randomized traffic — varying
//! fill, exact co-located ties (the mask-min tie-break case), and
//! multiple lanes, at N=64 and N=256 (PR 1 acceptance).
//!
//! These tests need no artifacts; they pin the native stepper's
//! numerics so the HLO cross-validation in `runtime_numerics.rs` keeps
//! a trustworthy baseline.

use webots_hpc::sumo::idm::{idm_accel_all, idm_accel_all_into, leader_scan, wall_accel};
use webots_hpc::sumo::mobil::{decide_all, decide_all_into, lane_gap_scan, MobilParams};
use webots_hpc::sumo::state::{DriverParams, Traffic};
use webots_hpc::sumo::{
    LaneIndex, MergeScenario, NativeIdmStepper, ReferenceIdmStepper, Stepper,
};
use webots_hpc::util::Rng64;

/// Random traffic with deliberate pathologies: partial fill, exact
/// co-located x ties (same and different lanes), heterogeneous params.
fn random_traffic(rng: &mut Rng64, cap: usize, fill: f64) -> Traffic {
    let mut t = Traffic::new(cap);
    let mut x = 0.0f32;
    for _ in 0..cap {
        if rng.gen_f64() >= fill {
            continue;
        }
        x += 0.5 + rng.gen_range_f32(0.0, 40.0);
        let lane = rng.gen_below(3) as f32;
        let v = rng.gen_range_f32(0.0, 32.0);
        // ~20% of vehicles carry schema-3 exit intent so the exit-bias
        // branch and exit retirement ride every property sweep
        let exits = rng.gen_f64() < 0.2;
        let params = DriverParams {
            v0: rng.gen_range_f32(20.0, 38.0),
            t_headway: rng.gen_range_f32(0.9, 2.2),
            a_max: rng.gen_range_f32(1.0, 2.5),
            b_comf: rng.gen_range_f32(1.5, 3.5),
            s0: rng.gen_range_f32(1.5, 3.0),
            length: rng.gen_range_f32(4.0, 9.0),
            exit_pos: if exits {
                rng.gen_range_f32(100.0, 900.0)
            } else {
                0.0
            },
            exit_flag: if exits { 1.0 } else { 0.0 },
        };
        t.spawn(x, v, lane, params);
    }
    // exact co-located ties: teleport ~15% of actives onto an earlier
    // active's x (sometimes also its lane) — the mask-min tie-break case
    for i in 1..cap {
        if !t.is_active(i) || rng.gen_f64() >= 0.15 {
            continue;
        }
        let j = (rng.gen_below(i as u64)) as usize;
        if !t.is_active(j) {
            continue;
        }
        let lane = if rng.gen_f64() < 0.5 { t.lane(j) } else { t.lane(i) };
        t.set_state_row(i, t.x(j), t.v(i), lane, true);
    }
    t
}

#[test]
fn sweep_scans_bit_exact_with_reference() {
    for &cap in &[64usize, 256] {
        for &fill in &[0.2f64, 0.7, 1.0] {
            for seed in 0..12u64 {
                let mut rng = Rng64::seed_from_u64(seed * 7919 + cap as u64);
                let t = random_traffic(&mut rng, cap, fill);
                let mut idx = LaneIndex::new();
                idx.rebuild(&t);
                for i in 0..cap {
                    if !t.is_active(i) {
                        continue;
                    }
                    let a = idx.leader(&t, i);
                    let b = leader_scan(&t, i);
                    assert_eq!(
                        (a.gap.to_bits(), a.v.to_bits(), a.exists),
                        (b.gap.to_bits(), b.v.to_bits(), b.exists),
                        "leader N={cap} fill={fill} seed={seed} slot={i}: {a:?} vs {b:?}"
                    );
                    for target in [0.0f32, 1.0, 2.0] {
                        let g = idx.lane_gaps(&t, i, target);
                        let r = lane_gap_scan(&t, i, target);
                        assert_eq!(
                            (
                                g.lead_gap.to_bits(),
                                g.lead_v.to_bits(),
                                g.lag_gap.to_bits(),
                                g.lag_v.to_bits()
                            ),
                            (
                                r.lead_gap.to_bits(),
                                r.lead_v.to_bits(),
                                r.lag_gap.to_bits(),
                                r.lag_v.to_bits()
                            ),
                            "gaps N={cap} fill={fill} seed={seed} slot={i} target={target}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn sweep_accel_and_decisions_bit_exact() {
    let scenario = MergeScenario::default();
    let mobil = MobilParams::default();
    for &cap in &[64usize, 256] {
        for seed in 0..10u64 {
            let mut rng = Rng64::seed_from_u64(seed ^ 0xACCE1);
            let t = random_traffic(&mut rng, cap, 0.7);
            let mut idx = LaneIndex::new();
            idx.rebuild(&t);

            let reference = idm_accel_all(&t);
            let mut fast = Vec::new();
            idm_accel_all_into(&t, &idx, &mut fast);
            for i in 0..cap {
                assert_eq!(
                    fast[i].to_bits(),
                    reference[i].to_bits(),
                    "accel N={cap} seed={seed} slot={i}"
                );
            }

            // decisions use the wall-combined accel, like the stepper
            let combined: Vec<f32> = (0..cap)
                .map(|i| {
                    if t.is_active(i) {
                        reference[i].min(wall_accel(&t, i, &scenario))
                    } else {
                        0.0
                    }
                })
                .collect();
            let ref_dec = decide_all(&t, &combined, &scenario, &mobil);
            let mut fast_dec = Vec::new();
            decide_all_into(&t, &combined, &scenario, &mobil, &idx, &mut fast_dec);
            assert_eq!(fast_dec, ref_dec, "decisions N={cap} seed={seed}");
        }
    }
}

/// Whole rollouts: stepping the same world with the production stepper
/// and the reference oracle yields *identical* f32 state and observables
/// at every step (reused scratch does not drift).
#[test]
fn sweep_stepper_rollouts_bit_exact() {
    for &cap in &[64usize, 256] {
        for seed in 0..6u64 {
            let mut rng = Rng64::seed_from_u64(seed.wrapping_mul(0x9E3779B9) + cap as u64);
            let t0 = random_traffic(&mut rng, cap, 0.6);
            let mut ta = t0.clone();
            let mut tb = t0;
            let mut fast = NativeIdmStepper::default();
            let mut oracle = ReferenceIdmStepper::default();
            for step in 0..60 {
                let oa = fast.step(&mut ta);
                let ob = oracle.step(&mut tb);
                assert_eq!(oa, ob, "obs N={cap} seed={seed} step={step}");
                assert_eq!(
                    ta, tb,
                    "state diverged N={cap} seed={seed} step={step}"
                );
            }
        }
    }
}
