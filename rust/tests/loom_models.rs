//! Concurrency models for the control plane's three shared-state
//! protocols (ISSUE 9):
//!
//! 1. **LeaseTable expire-vs-complete** — the coordinator's reaper and
//!    a completing connection race for the same lease; exactly one
//!    side may settle it (double-settlement is the bug class the
//!    settlement-claim protocol in `fabric/coordinator.rs` exists to
//!    stop at the layer above).
//! 2. **Registry histogram/counter exactness** — concurrent `record`s
//!    and racing handle registration must lose no sample (the §5
//!    tables are integrals over these histograms; a lost sample is a
//!    silently wrong table).
//! 3. **SharedCache get-or-insert** — the executable pool's
//!    probe/build/insert protocol: a racing double-build collapses to
//!    one live entry and every caller gets a valid value.
//!
//! Two lanes, same invariants:
//!
//! * `RUSTFLAGS="--cfg loom" cargo test --release --test loom_models`
//!   runs them as **loom models** — every interleaving, exhaustively
//!   (check.sh's loom lane; needs the `loom` crate).
//! * plain `cargo test` runs them as **real-thread stress tests** —
//!   tier-1-visible, no extra dependencies.
//!
//! The lib compiles a reduced module set under loom (see lib.rs), and
//! `util::sync` swaps std primitives for loom's — so the models check
//! the exact code the campaign runs, not a transliteration.

#[cfg(loom)]
mod models {
    use loom::sync::{Arc, Mutex};
    use loom::thread;
    use std::time::{Duration, Instant};

    use webots_hpc::fabric::LeaseTable;
    use webots_hpc::telemetry::metrics::Registry;
    use webots_hpc::util::SharedCache;

    #[test]
    fn lease_expire_vs_complete_settles_exactly_once() {
        loom::model(|| {
            let base = Instant::now();
            let ttl = Duration::from_millis(10);
            let table = Arc::new(Mutex::new(LeaseTable::new(ttl)));
            let id = table.lock().unwrap().grant(7, "c-e0[7]", "w1#1", base).id;

            let reaper = {
                let table = Arc::clone(&table);
                thread::spawn(move || table.lock().unwrap().expired(base + ttl).len())
            };
            let completer = {
                let table = Arc::clone(&table);
                thread::spawn(move || usize::from(table.lock().unwrap().release(id).is_some()))
            };
            let reaped = reaper.join().unwrap();
            let completed = completer.join().unwrap();

            assert_eq!(reaped + completed, 1, "exactly one side settles the lease");
            let mut t = table.lock().unwrap();
            assert!(t.is_empty(), "no zombie lease survives the race");
            // requeue after expiry: the attempt counter keeps rising, so
            // the ledger's per-run attempt numbers stay monotonic
            assert_eq!(t.grant(7, "c-e0[7]", "w2#1", base + ttl).attempt, 2);
        });
    }

    #[test]
    fn histogram_and_counter_recording_is_exact() {
        loom::model(|| {
            let reg = Arc::new(Registry::default());
            let other = {
                let reg = Arc::clone(&reg);
                thread::spawn(move || {
                    // handle registration itself races with the main
                    // thread's — both must get the same instrument
                    reg.histogram("m.lat").record(3);
                    reg.counter("m.ops").inc();
                })
            };
            reg.histogram("m.lat").record(1000);
            reg.counter("m.ops").inc();
            other.join().unwrap();

            let snap = reg.snapshot();
            let h = &snap.histograms["m.lat"];
            assert_eq!(h.count, 2);
            assert_eq!(h.sum, 1003);
            assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
            assert_eq!(snap.counters["m.ops"], 2);
        });
    }

    #[test]
    fn cache_double_build_collapses_to_one_entry() {
        loom::model(|| {
            let cache: Arc<SharedCache<u64, u64>> = Arc::new(SharedCache::new());
            let other = {
                let cache = Arc::clone(&cache);
                thread::spawn(move || *cache.get_or_try_insert::<(), _>(7, || Ok(70)).unwrap().0)
            };
            let mine = *cache.get_or_try_insert::<(), _>(7, || Ok(70)).unwrap().0;
            let theirs = other.join().unwrap();

            assert_eq!((mine, theirs), (70, 70), "every caller gets a valid value");
            assert_eq!(cache.len(), 1, "a racing double-build leaves one entry");
            let (v, hit) = cache.get_or_try_insert::<(), _>(7, || Ok(999)).unwrap();
            assert!(hit, "after the race the key is always a hit");
            assert_eq!(*v, 70);
        });
    }
}

#[cfg(not(loom))]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod stress {
    use std::collections::HashSet;
    use std::sync::{Arc, Mutex};
    use std::time::{Duration, Instant};

    use webots_hpc::fabric::LeaseTable;
    use webots_hpc::telemetry::metrics::Registry;
    use webots_hpc::util::SharedCache;

    const THREADS: usize = 8;

    #[test]
    fn lease_expire_vs_complete_settles_exactly_once() {
        // all leases are already past deadline; completer threads race
        // the sweeping reaper for them — each lease settles once
        const LEASES: u64 = 64;
        let base = Instant::now();
        let table = Arc::new(Mutex::new(LeaseTable::new(Duration::ZERO)));
        let ids: Vec<u64> = (0..LEASES)
            .map(|i| {
                table
                    .lock()
                    .unwrap()
                    .grant(i, &format!("c-e0[{i}]"), "w1#1", base)
                    .id
            })
            .collect();

        let reaper = {
            let table = Arc::clone(&table);
            std::thread::spawn(move || {
                let mut reaped = Vec::new();
                loop {
                    let swept = table.lock().unwrap().expired(base + Duration::from_secs(1));
                    let empty = swept.is_empty();
                    reaped.extend(swept.into_iter().map(|l| l.id));
                    if empty && table.lock().unwrap().is_empty() {
                        return reaped;
                    }
                    std::thread::yield_now();
                }
            })
        };
        let completers: Vec<_> = ids
            .chunks(ids.len() / THREADS)
            .map(|chunk| {
                let table = Arc::clone(&table);
                let chunk = chunk.to_vec();
                std::thread::spawn(move || {
                    chunk
                        .into_iter()
                        .filter(|id| table.lock().unwrap().release(*id).is_some())
                        .collect::<Vec<u64>>()
                })
            })
            .collect();

        let mut settled = HashSet::new();
        for id in reaper.join().unwrap() {
            assert!(settled.insert(id), "lease {id} reaped twice");
        }
        for t in completers {
            for id in t.join().unwrap() {
                assert!(settled.insert(id), "lease {id} settled twice");
            }
        }
        assert_eq!(settled.len() as u64, LEASES, "every lease settles exactly once");
        assert!(table.lock().unwrap().is_empty());
    }

    #[test]
    fn histogram_and_counter_recording_is_exact() {
        // racing handle registration + recording: nothing may be lost
        const PER: u64 = 2000;
        let reg = Arc::new(Registry::default());
        std::thread::scope(|s| {
            for t in 0..THREADS as u64 {
                let reg = Arc::clone(&reg);
                s.spawn(move || {
                    for i in 0..PER {
                        // re-fetch handles every iteration so the
                        // registry's get-or-insert path stays contended
                        reg.histogram("stress.lat").record(t * 1000 + i % 100);
                        reg.counter("stress.ops").inc();
                    }
                });
            }
        });
        let snap = reg.snapshot();
        let h = &snap.histograms["stress.lat"];
        assert_eq!(h.count, THREADS as u64 * PER);
        let expected: u64 = (0..THREADS as u64)
            .map(|t| (0..PER).map(|i| t * 1000 + i % 100).sum::<u64>())
            .sum();
        assert_eq!(h.sum, expected);
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
        assert_eq!(snap.counters["stress.ops"], THREADS as u64 * PER);
    }

    #[test]
    fn cache_double_build_collapses_to_one_entry_per_key() {
        const KEYS: u64 = 4;
        let cache: Arc<SharedCache<u64, u64>> = Arc::new(SharedCache::new());
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let cache = Arc::clone(&cache);
                s.spawn(move || {
                    for k in 0..KEYS {
                        let (v, _hit) =
                            cache.get_or_try_insert::<(), _>(k, || Ok(k * 10)).unwrap();
                        assert_eq!(*v, k * 10, "every caller gets the key's value");
                    }
                });
            }
        });
        assert_eq!(cache.len() as u64, KEYS, "races collapse to one entry per key");
        for k in 0..KEYS {
            assert_eq!(*cache.get(&k).unwrap(), k * 10);
        }
    }
}
