//! Telemetry acceptance tests (ISSUE 7).
//!
//! Two properties anchor the observability layer:
//!
//! 1. **Golden trace export** — the Chrome/Perfetto conversion is
//!    byte-stable: a fixed event stream must serialize to the exact
//!    committed document (`tests/golden/chrome_trace.json`, generated
//!    by the independent python mirror in
//!    `scripts/verify_telemetry.py`).  Perfetto consumes this format
//!    verbatim, so byte drift is format drift.
//!
//! 2. **Events ⊇ ledger** — a fault-injected supervised campaign's
//!    event stream must reconstruct the ledger's completion facts
//!    without reading the ledger file.  This is the contract the
//!    planned coordinator/worker fabric relies on: workers stream
//!    events, the coordinator must not need their ledger files.
//!
//! The event sink registry is process-global and `cargo test` runs
//! tests concurrently, so every assertion filters the captured stream
//! down to this test's own campaign/run ids before counting.

use webots_hpc::pipeline::{
    run_supervised_campaign, CampaignLedger, FaultPlan, PhysicsEngine, RetryPolicy,
    SupervisedCampaignSpec, SupervisorSpec,
};
use webots_hpc::telemetry::{
    self, read_events, summarize, to_chrome_trace, Event, EventKind, JsonlSink,
};
use webots_hpc::util::TempDir;

fn ev(t_us: u64, kind: EventKind) -> Event {
    Event { t_us, kind }
}

/// The fixed stream behind the golden trace: one run, a transient
/// retry, a coalesced rollout dispatch, a ledger transition.
fn golden_events() -> Vec<Event> {
    let run = "golden-e0[0]".to_string();
    vec![
        ev(
            100,
            EventKind::RunBegin {
                run_id: run.clone(),
                epoch: 0,
                slot: 0,
                node: 0,
            },
        ),
        ev(
            110,
            EventKind::AttemptBegin {
                run_id: run.clone(),
                attempt: 0,
                engine: "hlo".into(),
            },
        ),
        ev(
            150,
            EventKind::AttemptEnd {
                run_id: run.clone(),
                attempt: 0,
                ok: false,
            },
        ),
        ev(
            160,
            EventKind::Retry {
                run_id: run.clone(),
                attempt: 0,
                class: "transient".into(),
                error: "TraCI port 8873 already in use".into(),
                backoff_ms: 5,
            },
        ),
        ev(
            170,
            EventKind::AttemptBegin {
                run_id: run.clone(),
                attempt: 1,
                engine: "hlo".into(),
            },
        ),
        ev(
            300,
            EventKind::DispatchEnd {
                kind: "rollout".into(),
                bucket: 64,
                k: 32,
                batch: 2,
                dur_us: 40,
            },
        ),
        ev(
            400,
            EventKind::AttemptEnd {
                run_id: run.clone(),
                attempt: 1,
                ok: true,
            },
        ),
        ev(
            410,
            EventKind::LedgerTransition {
                run_id: run.clone(),
                state: "completed".into(),
            },
        ),
        ev(
            420,
            EventKind::RunEnd {
                run_id: run,
                ok: true,
                attempts: 2,
                degraded: false,
            },
        ),
    ]
}

#[test]
fn chrome_trace_export_matches_golden() {
    let doc = to_chrome_trace(&golden_events());
    let golden = include_str!("golden/chrome_trace.json");
    assert_eq!(
        doc.to_compact_string(),
        golden.trim_end(),
        "trace-event export drifted from tests/golden/chrome_trace.json \
         (regenerate with scripts/verify_telemetry.py --golden if the \
         change is intentional)"
    );
    // and the document round-trips through the crate's own parser
    let parsed = webots_hpc::util::Json::parse(golden.trim_end()).unwrap();
    assert_eq!(parsed, doc);
}

#[test]
fn golden_stream_report_is_consistent() {
    let report = summarize(&golden_events());
    assert_eq!(report.runs_seen, 1);
    assert_eq!(report.completed, 1);
    assert_eq!(report.completion_rate(), 1.0);
    assert_eq!(report.attempts, 2);
    assert_eq!(report.retries["transient"], 1);
    assert_eq!(report.backoff_ms_total, 5);
    let rollout = &report.dispatch[&("rollout".to_string(), 32)];
    assert_eq!(rollout.count, 1);
    assert_eq!(rollout.batched, 1);
}

/// Does this event belong to the given campaign?  The process-global
/// sink sees every concurrently-running test's events; ownership is
/// decided by the `run_id`/`name` the event itself carries.
fn belongs_to(ev: &Event, campaign: &str) -> bool {
    let j = ev.to_json();
    for key in ["run_id", "name"] {
        if let Ok(v) = j.get(key) {
            if let Ok(s) = v.as_str() {
                return s.starts_with(campaign);
            }
        }
    }
    false
}

#[test]
fn supervised_campaign_events_reconstruct_the_ledger() {
    let campaign = "telem-soak";
    let runs: u32 = 6;
    let dir = TempDir::new("telemetry-e2e").unwrap();
    let events_path = dir.path().join("events.jsonl");

    let sink: std::sync::Arc<dyn telemetry::EventSink> =
        std::sync::Arc::new(JsonlSink::append(&events_path).unwrap());
    telemetry::install(sink.clone());

    let spec = SupervisedCampaignSpec {
        name: campaign.into(),
        nodes: 1,
        slots_per_node: runs,
        epochs: 1,
        horizon_s: 2.0,
        capacity: 64,
        seed: 1000,
        matrix: None,
        supervisor: SupervisorSpec {
            retry: RetryPolicy {
                max_attempts: 10,
                base_ms: 1,
                cap_ms: 5,
            },
            watchdog: Default::default(),
            degrade: false,
            // the robustness soak's schedule: seeded, ≥10% per
            // transient site per attempt — the retry machinery fires
            fault_plan: Some(FaultPlan::transient_only(99, 0.12)),
        },
        ledger_dir: dir.path().to_path_buf(),
        retry_failed: false,
        stop_after_runs: None,
    };
    let outcome = run_supervised_campaign(&spec, &PhysicsEngine::Native);
    telemetry::uninstall(&sink);
    let outcome = outcome.unwrap();
    let stats = outcome.result.robustness.unwrap();
    assert_eq!(stats.completed, runs as u64, "soak converges");

    // the stream on disk, scoped to THIS campaign's ids
    let events: Vec<Event> = read_events(&events_path)
        .unwrap()
        .into_iter()
        .filter(|e| belongs_to(e, campaign))
        .collect();
    assert!(!events.is_empty());

    // events ⊇ ledger: every terminal ledger record has a matching
    // LedgerTransition event for the same run_id and state
    let ledger = CampaignLedger::open(dir.path().join("ledger.jsonl")).unwrap();
    for (run_id, _) in ledger.completed() {
        assert!(
            events.iter().any(|e| matches!(
                &e.kind,
                EventKind::LedgerTransition { run_id: r, state } if *r == run_id && state == "completed"
            )),
            "no completed event for {run_id}"
        );
    }

    // the report reproduces the §5.1 facts from the stream alone
    let report = summarize(&events);
    assert_eq!(report.campaign.as_deref(), Some(campaign));
    assert_eq!(report.runs_seen, runs as u64);
    assert_eq!(report.completed, ledger.completed().len() as u64);
    assert_eq!(report.completion_rate(), 1.0);
    // retry taxonomy agrees with the supervisor's own accounting
    assert_eq!(
        report.retries.values().sum::<u64>(),
        stats.retries,
        "event-stream retry count == RobustnessStats.retries"
    );
    assert_eq!(report.attempts, stats.attempts);
    assert_eq!(report.backoff_ms_total, stats.backoff_ms_total);

    // per-run attempt timeline: RunEnd attempts match the reports
    for run_report in &outcome.reports {
        let end = events.iter().find_map(|e| match &e.kind {
            EventKind::RunEnd {
                run_id, attempts, ..
            } if *run_id == run_report.run_id => Some(*attempts),
            _ => None,
        });
        assert_eq!(end, Some(run_report.attempts as u64), "{}", run_report.run_id);
    }

    // and the trace export covers every run with a span
    let doc = to_chrome_trace(&events);
    let rows = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let run_spans = rows
        .iter()
        .filter(|r| matches!(r.get("cat").and_then(|c| c.as_str()), Ok("run")))
        .count();
    assert_eq!(run_spans, runs as usize);
}

#[test]
fn resumed_campaign_extends_the_same_stream() {
    let campaign = "telem-resume";
    let dir = TempDir::new("telemetry-resume").unwrap();
    let events_path = dir.path().join("events.jsonl");
    let spec = |stop: Option<u64>| SupervisedCampaignSpec {
        name: campaign.into(),
        nodes: 1,
        slots_per_node: 4,
        epochs: 1,
        horizon_s: 2.0,
        capacity: 64,
        seed: 500,
        matrix: None,
        supervisor: SupervisorSpec::default(),
        ledger_dir: dir.path().to_path_buf(),
        retry_failed: false,
        stop_after_runs: stop,
    };

    // session 1: killed after 2 launches
    {
        let sink: std::sync::Arc<dyn telemetry::EventSink> =
            std::sync::Arc::new(JsonlSink::append(&events_path).unwrap());
        telemetry::install(sink.clone());
        let out = run_supervised_campaign(&spec(Some(2)), &PhysicsEngine::Native);
        telemetry::uninstall(&sink);
        assert!(out.unwrap().interrupted);
    }
    // session 2: resumes, appends to the same stream
    {
        let sink: std::sync::Arc<dyn telemetry::EventSink> =
            std::sync::Arc::new(JsonlSink::append(&events_path).unwrap());
        telemetry::install(sink.clone());
        let out = run_supervised_campaign(&spec(None), &PhysicsEngine::Native);
        telemetry::uninstall(&sink);
        assert!(!out.unwrap().interrupted);
    }

    let events: Vec<Event> = read_events(&events_path)
        .unwrap()
        .into_iter()
        .filter(|e| belongs_to(e, campaign))
        .collect();
    // both sessions opened the campaign; all 4 runs completed exactly
    // once across the two sessions (resume skips, never re-runs)
    let begins = events
        .iter()
        .filter(|e| matches!(&e.kind, EventKind::CampaignBegin { .. }))
        .count();
    assert_eq!(begins, 2, "one CampaignBegin per session");
    let report = summarize(&events);
    assert_eq!(report.completed, 4);
    assert_eq!(report.completion_rate(), 1.0);
    let run_begins = events
        .iter()
        .filter(|e| matches!(&e.kind, EventKind::RunBegin { .. }))
        .count();
    assert_eq!(run_begins, 4, "resume skipped settled runs");
}
