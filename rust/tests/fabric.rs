//! Distributed-fabric acceptance tests — the §5.1 completion claim
//! taken across process boundaries.
//!
//! Workers here run as in-process threads, but nothing they share with
//! the coordinator is in-memory: every exchange crosses a real loopback
//! TCP socket as newline-delimited JSON, every worker builds its own
//! container env / display registry / scenario registry, and the
//! coordinator's only durable state is the crash-safe ledger.  The soak
//! injects ≥ 10% transport faults per fabric site (dropped connections,
//! torn frames, duplicate completions), one hard worker kill, one
//! zombie worker, and one coordinator kill/resume — and still requires
//! `completion_rate() == 1.0` with an aggregate byte-identical to the
//! single-process driver's.

use webots_hpc::fabric::{
    run_worker, Coordinator, FabricConfig, WorkerConfig, WorkerKill, WorkerOutcome,
};
use webots_hpc::pipeline::{
    run_supervised_campaign, FaultPlan, FaultSite, PhysicsEngine, RetryPolicy,
    SupervisedCampaignSpec, SupervisorSpec,
};
use webots_hpc::util::TempDir;
use webots_hpc::webots::WatchdogSpec;

/// Same proven-converging schedule as the local soak: plan seed 99 over
/// run seeds 1000.. settles within 10 attempts at a 12% per-site rate.
const PLAN_SEED: u64 = 99;
const BASE_SEED: u64 = 1000;

/// 2 nodes × 3 slots × 2 epochs = 12 runs, soaked with the same in-run
/// transient-fault schedule the single-process soak proves out.
fn fabric_spec(name: &str, ledger_dir: std::path::PathBuf) -> SupervisedCampaignSpec {
    SupervisedCampaignSpec {
        name: name.into(),
        nodes: 2,
        slots_per_node: 3,
        epochs: 2,
        horizon_s: 2.0,
        capacity: 64,
        seed: BASE_SEED,
        matrix: None,
        supervisor: SupervisorSpec {
            retry: RetryPolicy {
                max_attempts: 10,
                base_ms: 1,
                cap_ms: 5,
            },
            watchdog: WatchdogSpec::default(),
            degrade: false,
            fault_plan: Some(FaultPlan::transient_only(PLAN_SEED, 0.12)),
        },
        ledger_dir,
        retry_failed: false,
        stop_after_runs: None,
    }
}

/// Test-speed fabric timings: 25ms heartbeats under a 150ms TTL keep a
/// healthy worker safe by 6× while the reaper notices a dead one fast.
fn fabric_cfg() -> FabricConfig {
    FabricConfig {
        port: 0,
        heartbeat_ms: 25,
        lease_ttl_ms: 150,
        stop_after_completions: None,
    }
}

fn worker(name: &str, port: u16, spec: &SupervisedCampaignSpec) -> WorkerConfig {
    WorkerConfig {
        reconnect_attempts: 64,
        reconnect_delay_ms: 10,
        ..WorkerConfig::new(name, format!("127.0.0.1:{port}"), spec.clone())
    }
}

fn spawn_worker(cfg: WorkerConfig) -> std::thread::JoinHandle<WorkerOutcome> {
    std::thread::spawn(move || run_worker(&cfg, &PhysicsEngine::Native).unwrap())
}

/// The headline distributed claim: a campaign spread over three flaky
/// workers — one injecting ≥ 10% transport faults per fabric site, one
/// killed hard while holding a lease, one zombified mid-lease — and a
/// coordinator killed after four accepted completions, still converges
/// on resume to 100% completion with zero duplicate run_ids and an
/// aggregate export byte-identical to the single-process driver's.
#[test]
fn distributed_soak_completes_100_percent_across_coordinator_kill() {
    let dir = TempDir::new("webots-hpc-fabric-soak").unwrap();
    let control_dir = TempDir::new("webots-hpc-fabric-control").unwrap();
    let spec = fabric_spec("fabric", dir.path().to_path_buf());

    // session 1: the coordinator's kill seam fires after 4 accepted
    // completions, abandoning everything else in flight
    let coord = Coordinator::bind(
        spec.clone(),
        FabricConfig {
            stop_after_completions: Some(4),
            ..fabric_cfg()
        },
    )
    .unwrap();
    let port = coord.port();
    let transport = FaultPlan::transport_only(PLAN_SEED, 0.15)
        .with_rate(FaultSite::FabricDuplicate, 0.25);
    let flaky = spawn_worker(WorkerConfig {
        transport_faults: Some(transport),
        ..worker("flaky", port, &spec)
    });
    let doomed = spawn_worker(WorkerConfig {
        kill: WorkerKill::DieAfter(0),
        ..worker("doomed", port, &spec)
    });
    let zombie = spawn_worker(WorkerConfig {
        kill: WorkerKill::ZombieAfter(0),
        ..worker("zombie", port, &spec)
    });
    let killed = coord.run().unwrap();
    assert!(doomed.join().unwrap().died, "the hard kill fired");
    assert!(zombie.join().unwrap().died, "the zombie seam fired");
    let _ = flaky.join().unwrap();

    assert!(killed.interrupted, "4 < 12: work was abandoned in flight");
    assert!(killed.fabric.completions_accepted >= 4);
    assert!(
        killed.fabric.leases_expired >= 1,
        "the killed worker's lease was revoked: {:?}",
        killed.fabric
    );

    // session 2: a fresh coordinator on the same ledger dir, clean
    // workers — the campaign must settle completely
    let coord = Coordinator::bind(spec.clone(), fabric_cfg()).unwrap();
    let port = coord.port();
    let workers: Vec<_> = (0..3)
        .map(|i| spawn_worker(worker(&format!("w{i}"), port, &spec)))
        .collect();
    let outcome = coord.run().unwrap();
    for w in workers {
        let _ = w.join().unwrap();
    }

    assert!(!outcome.interrupted);
    let stats = outcome.result.robustness.expect("supervised accounting");
    assert_eq!(stats.runs, 12);
    assert_eq!(stats.completed, 12);
    assert_eq!(stats.failed, 0);
    assert_eq!(
        stats.completion_rate(),
        1.0,
        "the distributed §5.1 claim: {stats:?}"
    );
    assert!(
        stats.resumed_skips >= 4,
        "session 1's completions were skipped, not re-run: {stats:?}"
    );
    assert_eq!(outcome.dataset.num_runs(), 12);
    assert!(
        outcome.dataset.run_ids_unique(),
        "no duplicate run_ids survived re-dispatch + the zombie"
    );
    assert!(outcome.dataset.seeds_unique());

    // control: the identical campaign, single-process, fresh ledger
    let control_spec = fabric_spec("fabric", control_dir.path().to_path_buf());
    let control = run_supervised_campaign(&control_spec, &PhysicsEngine::Native).unwrap();
    assert_eq!(
        outcome.dataset.to_ml_csv(),
        control.dataset.to_ml_csv(),
        "distributed aggregate must be byte-identical to the single-process driver's"
    );
}

/// The check.sh smoke: two workers over loopback, one killed hard on
/// its first lease, the other retransmitting every completion — the
/// campaign still settles at 100% and every retransmission lands in the
/// duplicate guard.
#[test]
fn fabric_smoke_two_workers_one_kill() {
    let dir = TempDir::new("webots-hpc-fabric-smoke").unwrap();
    let mut spec = fabric_spec("smoke", dir.path().to_path_buf());
    spec.nodes = 1;
    spec.slots_per_node = 4;
    spec.epochs = 1;
    spec.supervisor.fault_plan = None;

    let coord = Coordinator::bind(spec.clone(), fabric_cfg()).unwrap();
    let port = coord.port();
    // rate 1.0: every completion is followed by a duplicate retransmit
    let dup = FaultPlan::none(PLAN_SEED).with_rate(FaultSite::FabricDuplicate, 1.0);
    let dup_worker = spawn_worker(WorkerConfig {
        transport_faults: Some(dup),
        ..worker("dup", port, &spec)
    });
    let doomed = spawn_worker(WorkerConfig {
        kill: WorkerKill::DieAfter(0),
        ..worker("doomed", port, &spec)
    });

    let outcome = coord.run().unwrap();
    assert!(doomed.join().unwrap().died);
    let dup_out = dup_worker.join().unwrap();
    assert!(dup_out.completions >= 1);

    assert!(!outcome.interrupted);
    let stats = outcome.result.robustness.unwrap();
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.completion_rate(), 1.0, "{stats:?}");
    assert_eq!(outcome.fabric.completions_accepted, 4);
    assert!(
        outcome.fabric.completions_rejected >= 1,
        "retransmits must hit the duplicate guard: {:?}",
        outcome.fabric
    );
    assert!(
        outcome.fabric.leases_expired >= 1,
        "the dead worker's lease was revoked: {:?}",
        outcome.fabric
    );
    assert!(outcome.dataset.run_ids_unique());
}

/// A worker whose spec drifted from the coordinator's (here: a
/// different seed grid) must be refused at the handshake — before it
/// can lease work and settle runs under the wrong scenario sampling.
#[test]
fn mismatched_spec_hash_is_refused_at_handshake() {
    let dir = TempDir::new("webots-hpc-fabric-refuse").unwrap();
    let mut spec = fabric_spec("refuse", dir.path().to_path_buf());
    spec.nodes = 1;
    spec.slots_per_node = 2;
    spec.epochs = 1;
    spec.supervisor.fault_plan = None;

    let coord = Coordinator::bind(spec.clone(), fabric_cfg()).unwrap();
    let port = coord.port();

    let mut drifted = spec.clone();
    drifted.seed += 1;
    let refused = spawn_worker(worker("drift", port, &drifted));
    let good = spawn_worker(worker("good", port, &spec));

    let outcome = coord.run().unwrap();
    let refused = refused.join().unwrap();
    let reason = refused.refused.expect("handshake must be refused");
    assert!(reason.contains("different campaign shape"), "{reason}");
    assert_eq!(refused.completions, 0, "refused workers lease nothing");
    let _ = good.join().unwrap();

    assert!(outcome.fabric.workers_refused >= 1, "{:?}", outcome.fabric);
    let stats = outcome.result.robustness.unwrap();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.completion_rate(), 1.0);
    assert!(outcome.dataset.run_ids_unique());
}
