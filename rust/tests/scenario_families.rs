//! Generated-config coverage for the scenario families (ISSUE 2
//! satellite): every shipped family must compile at its parameter
//! extremes into configs whose networks/flows survive the xmlio
//! round-trip and `validate_route`, be runnable end to end, and — on a
//! non-merge family — step bit-identically through the sweep-based
//! `NativeIdmStepper` and the O(N²) `ReferenceIdmStepper`.

use webots_hpc::scenario::{
    AxisKind, AxisValue, FamilyRegistry, ScenarioPoint, UniformSampler,
};
use webots_hpc::sumo::mobil::MobilParams;
use webots_hpc::sumo::{duarouter, xmlio, NativeIdmStepper, ReferenceIdmStepper, SumoSim};

/// The all-lo / all-hi corner points of a family's space.
fn extreme_points(registry: &FamilyRegistry, id: &str) -> Vec<ScenarioPoint> {
    let space = registry.get(id).unwrap().space();
    [false, true]
        .into_iter()
        .map(|hi| ScenarioPoint {
            family: space.family.clone(),
            index: hi as u64,
            seed: 0,
            values: space
                .axes
                .iter()
                .map(|a| match &a.kind {
                    AxisKind::Continuous { lo, hi: h } => {
                        AxisValue::Num(if hi { *h } else { *lo })
                    }
                    AxisKind::Integer { lo, hi: h } => AxisValue::Int(if hi { *h } else { *lo }),
                    AxisKind::Choice { options } => {
                        let pick = if hi { options.last() } else { options.first() };
                        AxisValue::Tag(pick.unwrap().clone())
                    }
                })
                .collect(),
        })
        .collect()
}

#[test]
fn every_family_compiles_and_roundtrips_at_extremes() {
    let registry = FamilyRegistry::builtin();
    for id in registry.ids() {
        let family = registry.get(&id).unwrap();
        for point in extreme_points(&registry, &id) {
            let cfg = family
                .compile(&point)
                .unwrap_or_else(|e| panic!("{id} extreme #{}: {e}", point.index));

            // routes exist and connect on the compiled network
            cfg.flows.validate(&cfg.network).unwrap();
            for flow in &cfg.flows.flows {
                cfg.network.validate_route(&flow.route).unwrap();
            }

            // xmlio round-trips (the world-copy propagation path)
            let net_back = xmlio::read_net_xml(&xmlio::write_net_xml(&cfg.network)).unwrap();
            assert_eq!(cfg.network, net_back, "{id} net.xml");
            let flows_back = xmlio::read_flow_xml(&xmlio::write_flow_xml(&cfg.flows)).unwrap();
            assert_eq!(cfg.flows, flows_back, "{id} flow.xml");

            // duarouter accepts the compiled tuple
            let routes = duarouter(&cfg.network, &cfg.flows, 1).unwrap();
            assert!(
                !routes.departures.is_empty(),
                "{id} extreme #{} schedules departures",
                point.index
            );

            // geometry stays inside the stepper's assumptions
            assert!(cfg.geometry.num_main_lanes >= 1, "{id}");
            assert!(cfg.geometry.road_end_m > 0.0, "{id}");
            assert!(cfg.geometry.merge_end_m >= cfg.geometry.merge_start_m, "{id}");
            assert!(cfg.capacity >= 16, "{id}");
        }
    }
}

#[test]
fn lane_drop_reference_and_native_steppers_agree_exactly() {
    // reference-vs-native agreement on a non-merge family: identical
    // observables AND identical state arrays, step by step
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("lane-drop", &UniformSampler, 11, 0)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 9).unwrap();

    let mut native = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes.clone(),
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let mut reference = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(ReferenceIdmStepper {
            scenario: cfg.geometry,
            mobil: MobilParams::default(),
        }),
    );

    for step in 0..600 {
        let a = native.step();
        let b = reference.step();
        assert_eq!(a, b, "observables diverged at step {step}");
        assert_eq!(native.traffic, reference.traffic, "state diverged at step {step}");
    }
    assert!(native.total_spawned > 0, "lane-drop demand spawned");
}

#[test]
fn lane_drop_bottleneck_forces_merges() {
    // vehicles on the dropping lane must merge out inside the taper —
    // the n_merged observable counts exactly those lane-0 escapes
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("lane-drop", &UniformSampler, 21, 1)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 4).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 0);
    assert!(sim.total_merged > 0.0, "drop-lane traffic merged out");
}

#[test]
fn ring_shockwave_runs_and_circulates() {
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("ring-shockwave", &UniformSampler, 5, 2)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 2).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let obs = sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 5, "burst packs the ring");
    // traffic stays on the road well past the burst window (the
    // unrolled ring is laps long)
    let active_late = obs[obs.len() / 4].n_active;
    assert!(active_late > 0.0, "platoon still circulating at quarter-horizon");
    // no vehicle ever uses lane 0 (the ring has no ramp lane)
    let t = &sim.traffic;
    for i in 0..t.capacity() {
        if t.is_active(i) {
            assert!(t.lane(i) >= 0.5, "vehicle {i} on the unused ramp lane");
        }
    }
}

#[test]
fn ramp_weave_on_traffic_merges_before_weave_end() {
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("ramp-weave", &UniformSampler, 8, 3)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 6).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 0);
    assert!(sim.total_merged > 0.0, "auxiliary-lane traffic merged");
    // the off-ramp edge is part of the compiled graph
    assert!(cfg.network.edge("off_ramp").is_ok());
}
