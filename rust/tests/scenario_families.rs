//! Generated-config coverage for the scenario families (ISSUE 2
//! satellite): every shipped family must compile at its parameter
//! extremes into configs whose networks/flows survive the xmlio
//! round-trip and `validate_route`, be runnable end to end, and — on a
//! non-merge family — step bit-identically through the sweep-based
//! `NativeIdmStepper` and the O(N²) `ReferenceIdmStepper`.
//!
//! ISSUE 3 extends this with the geometry-operand contract: on **all
//! four** families, at the axis extremes, the geometry-generic AOT
//! artifact (via [`HloStepper`]) must track the native stepper within
//! f32 tolerance (EXPERIMENTS.md §Perf methodology), and sessions
//! running *different* families must coalesce in the micro-batcher
//! without cross-lane geometry contamination.  The HLO tests no-op with
//! a note when `make artifacts` hasn't run.

use webots_hpc::runtime::{EngineService, HloStepper};
use webots_hpc::scenario::{
    AxisKind, AxisValue, FamilyRegistry, ScenarioPoint, UniformSampler,
};
use webots_hpc::sumo::mobil::MobilParams;
use webots_hpc::sumo::{
    duarouter, xmlio, NativeIdmStepper, ReferenceIdmStepper, Stepper, SumoSim, Traffic,
};

/// The all-lo / all-hi corner points of a family's space.
fn extreme_points(registry: &FamilyRegistry, id: &str) -> Vec<ScenarioPoint> {
    let space = registry.get(id).unwrap().space();
    [false, true]
        .into_iter()
        .map(|hi| ScenarioPoint {
            family: space.family.clone(),
            index: hi as u64,
            seed: 0,
            values: space
                .axes
                .iter()
                .map(|a| match &a.kind {
                    AxisKind::Continuous { lo, hi: h } => {
                        AxisValue::Num(if hi { *h } else { *lo })
                    }
                    AxisKind::Integer { lo, hi: h } => AxisValue::Int(if hi { *h } else { *lo }),
                    AxisKind::Choice { options } => {
                        let pick = if hi { options.last() } else { options.first() };
                        AxisValue::Tag(pick.unwrap().clone())
                    }
                })
                .collect(),
        })
        .collect()
}

#[test]
fn every_family_compiles_and_roundtrips_at_extremes() {
    let registry = FamilyRegistry::builtin();
    for id in registry.ids() {
        let family = registry.get(&id).unwrap();
        for point in extreme_points(&registry, &id) {
            let cfg = family
                .compile(&point)
                .unwrap_or_else(|e| panic!("{id} extreme #{}: {e}", point.index));

            // routes exist and connect on the compiled network
            cfg.flows.validate(&cfg.network).unwrap();
            for flow in &cfg.flows.flows {
                cfg.network.validate_route(&flow.route).unwrap();
            }

            // xmlio round-trips (the world-copy propagation path)
            let net_back = xmlio::read_net_xml(&xmlio::write_net_xml(&cfg.network)).unwrap();
            assert_eq!(cfg.network, net_back, "{id} net.xml");
            let flows_back = xmlio::read_flow_xml(&xmlio::write_flow_xml(&cfg.flows)).unwrap();
            assert_eq!(cfg.flows, flows_back, "{id} flow.xml");

            // duarouter accepts the compiled tuple
            let routes = duarouter(&cfg.network, &cfg.flows, 1).unwrap();
            assert!(
                !routes.departures.is_empty(),
                "{id} extreme #{} schedules departures",
                point.index
            );

            // geometry stays inside the stepper's assumptions
            assert!(cfg.geometry.num_main_lanes >= 1, "{id}");
            assert!(cfg.geometry.road_end_m > 0.0, "{id}");
            assert!(cfg.geometry.merge_end_m >= cfg.geometry.merge_start_m, "{id}");
            assert!(cfg.capacity >= 16, "{id}");
        }
    }
}

#[test]
fn lane_drop_reference_and_native_steppers_agree_exactly() {
    // reference-vs-native agreement on a non-merge family: identical
    // observables AND identical state arrays, step by step
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("lane-drop", &UniformSampler, 11, 0)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 9).unwrap();

    let mut native = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes.clone(),
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let mut reference = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(ReferenceIdmStepper {
            scenario: cfg.geometry,
            mobil: MobilParams::default(),
        }),
    );

    for step in 0..600 {
        let a = native.step();
        let b = reference.step();
        assert_eq!(a, b, "observables diverged at step {step}");
        assert_eq!(native.traffic, reference.traffic, "state diverged at step {step}");
    }
    assert!(native.total_spawned > 0, "lane-drop demand spawned");
}

#[test]
fn lane_drop_bottleneck_forces_merges() {
    // vehicles on the dropping lane must merge out inside the taper —
    // the n_merged observable counts exactly those lane-0 escapes
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("lane-drop", &UniformSampler, 21, 1)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 4).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 0);
    assert!(sim.total_merged > 0.0, "drop-lane traffic merged out");
}

#[test]
fn ring_shockwave_runs_and_circulates() {
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("ring-shockwave", &UniformSampler, 5, 2)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 2).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let obs = sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 5, "burst packs the ring");
    // traffic stays on the road well past the burst window (the
    // unrolled ring is laps long)
    let active_late = obs[obs.len() / 4].n_active;
    assert!(active_late > 0.0, "platoon still circulating at quarter-horizon");
    // no vehicle ever uses lane 0 (the ring has no ramp lane)
    let t = &sim.traffic;
    for i in 0..t.capacity() {
        if t.is_active(i) {
            assert!(t.lane(i) >= 0.5, "vehicle {i} on the unused ramp lane");
        }
    }
}

fn service() -> Option<EngineService> {
    match EngineService::auto() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping PJRT scenario test: {e}");
            None
        }
    }
}

/// Native-vs-HLO agreement on ALL FOUR families at their axis extremes
/// (the ISSUE 3 coverage satellite).  Tolerances follow the
/// EXPERIMENTS.md §Perf methodology (`rust/tests/runtime_numerics.rs`):
/// both sides integrate the same f32 math in different op orders, so
/// short rollouts must track within 0.5 m / 0.5 m/s, with retirement
/// allowed to land one step apart at the road-end boundary.
#[test]
fn all_families_native_vs_hlo_track_at_extremes() {
    let Some(s) = service() else { return };
    let registry = FamilyRegistry::builtin();
    for id in registry.ids() {
        let family = registry.get(&id).unwrap();
        for point in extreme_points(&registry, &id) {
            let cfg = family.compile(&point).unwrap();
            if !s.manifest().buckets.contains(&cfg.capacity) {
                eprintln!(
                    "note: {id} extreme #{} needs capacity {} (lowered: {:?}); skipped",
                    point.index,
                    cfg.capacity,
                    s.manifest().buckets
                );
                continue;
            }
            // populate a realistic mid-run state through the native sim
            let routes = duarouter(&cfg.network, &cfg.flows, 13).unwrap();
            let mut warm = SumoSim::new(
                cfg.geometry,
                cfg.capacity,
                routes,
                Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
            );
            for _ in 0..150 {
                warm.step();
            }
            let t0 = warm.traffic.clone();
            assert!(
                t0.active_count() > 0,
                "{id} extreme #{}: warmup produced traffic",
                point.index
            );
            // the ramp-weave hi extreme (off_share = 0.3) must carry live
            // exit intent into the agreement rollout, so the schema-3
            // columns are exercised on the HLO path too
            if id == "ramp-weave" && point.index == 1 {
                use webots_hpc::sumo::state::P_EXIT_FLAG;
                let flagged = (0..cfg.capacity)
                    .filter(|&i| t0.is_active(i) && t0.param(i, P_EXIT_FLAG) > 0.5)
                    .count();
                assert!(flagged > 0, "warmup spawned exit-flagged traffic");
            }

            let mut t_nat = t0.clone();
            let mut t_hlo = t0.clone();
            let mut nat = NativeIdmStepper::new(cfg.geometry, MobilParams::default());
            let mut hlo =
                HloStepper::for_scenario(s.clone(), cfg.capacity, &cfg.geometry).unwrap();
            for step in 0..20 {
                let on = nat.step(&mut t_nat);
                let oh = hlo.step(&mut t_hlo);
                assert!(
                    (on.n_active - oh.n_active).abs() <= 1.0,
                    "{id} extreme #{} step {step}: active {} vs {}",
                    point.index,
                    on.n_active,
                    oh.n_active
                );
                for i in 0..cfg.capacity {
                    if !(t_nat.is_active(i) && t_hlo.is_active(i)) {
                        continue; // boundary retirement may land one step apart
                    }
                    assert!(
                        (t_nat.x(i) - t_hlo.x(i)).abs() < 0.5,
                        "{id} extreme #{} step {step} slot {i}: x {} vs {}",
                        point.index,
                        t_nat.x(i),
                        t_hlo.x(i)
                    );
                    assert!(
                        (t_nat.v(i) - t_hlo.v(i)).abs() < 0.5,
                        "{id} extreme #{} step {step} slot {i}: v {} vs {}",
                        point.index,
                        t_nat.v(i),
                        t_hlo.v(i)
                    );
                }
            }
        }
    }
}

/// Mixed-family micro-batcher coalescing: sessions carrying FOUR
/// different geometries at the same bucket step concurrently; each must
/// get exactly its own family's physics (a swapped or zeroed geometry
/// row would move the phantom wall / road end and show up immediately).
#[test]
fn mixed_family_sessions_coalesce_without_geometry_contamination() {
    let Some(s) = service() else { return };
    let bucket = s.manifest().buckets[0];
    let registry = FamilyRegistry::builtin();

    // one compiled geometry per family + a deterministic world sized to
    // the family's own road (so road-end/wall effects differ per lane)
    let mut worlds = Vec::new();
    for (k, id) in registry.ids().into_iter().enumerate() {
        let (_, cfg) = registry.materialize(&id, &UniformSampler, 31, k as u64).unwrap();
        let mut t = Traffic::new(bucket);
        let span = cfg.geometry.road_end_m * 0.9;
        for i in 0..(bucket / 2) {
            let frac = (i as f32 + 1.0) / (bucket / 2 + 1) as f32;
            let lane = 1.0 + (i % cfg.geometry.num_main_lanes.max(1) as usize) as f32;
            t.spawn(
                span * frac,
                5.0 + (k as f32) * 3.0 + i as f32,
                lane,
                webots_hpc::sumo::DriverParams::default(),
            );
        }
        worlds.push((id, cfg.geometry, t));
    }

    // solo references per family (same executable, no coalescing)
    let expect: Vec<_> = worlds
        .iter()
        .map(|(_, geom, t)| {
            s.step_geom(bucket, &t.state, &t.params, geom.geometry_vec())
                .unwrap()
        })
        .collect();
    // geometries genuinely differ — so would their results
    for (a, b) in expect.iter().zip(expect.iter().skip(1)) {
        assert_ne!(a.state, b.state, "test premise: distinct worlds");
    }

    // 8 threads = 2 sessions per family, stepping in lock-step so the
    // micro-batcher coalesces mixed-geometry requests into one dispatch
    for _ in 0..3 {
        std::thread::scope(|scope| {
            for dup in 0..2 {
                for ((id, geom, t), e) in worlds.iter().zip(expect.iter()) {
                    let svc = s.clone();
                    scope.spawn(move || {
                        let mut sess = svc.session_for(bucket, geom.geometry_vec()).unwrap();
                        for round in 0..10 {
                            let out = sess.step(&t.state, &t.params).unwrap();
                            for (a, c) in out.state.iter().zip(e.state.iter()) {
                                assert!(
                                    (a - c).abs() < 1e-4,
                                    "{id} dup {dup} round {round}: got another family's physics"
                                );
                            }
                        }
                    });
                }
            }
        });
    }
    s.shutdown();
}

/// A ramp-weave point with pinned axis values (everything else a
/// mid-range default) — the fixed-seed ISSUE 4 acceptance scenario.
fn ramp_weave_point(registry: &FamilyRegistry, off_share: f64) -> ScenarioPoint {
    let space = registry.get("ramp-weave").unwrap().space();
    let values = space
        .axes
        .iter()
        .map(|a| match a.name.as_str() {
            "main_vph" => AxisValue::Num(1600.0),
            "on_vph" => AxisValue::Num(300.0),
            "off_share" => AxisValue::Num(off_share),
            "main_lanes" => AxisValue::Int(2),
            "weave_len_m" => AxisValue::Num(250.0),
            "cav_penetration" => AxisValue::Num(0.0),
            "speed_limit" => AxisValue::Num(30.0),
            "t_scale" => AxisValue::Num(1.0),
            other => panic!("unexpected ramp-weave axis '{other}'"),
        })
        .collect();
    ScenarioPoint {
        family: space.family.clone(),
        index: 0,
        seed: 0,
        values,
    }
}

/// ISSUE 4 acceptance: at `off_share = 0.25`, >= 80% of the off-flow
/// demand retires via the off-ramp gore (exits *before* the road end),
/// and at `off_share = 0` nothing exits.  Fixed seed; native sweep
/// stepper; run long enough past the demand window to drain.
#[test]
fn ramp_weave_off_traffic_actually_exits() {
    let registry = FamilyRegistry::builtin();
    let family = registry.get("ramp-weave").unwrap();

    let cfg = family.compile(&ramp_weave_point(&registry, 0.25)).unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 17).unwrap();
    let n_off = routes
        .departures
        .iter()
        .filter(|d| d.id.starts_with("off"))
        .count();
    assert!(n_off > 10, "off demand scheduled: {n_off}");
    // every off departure carries the compiled destination
    assert!(routes
        .departures
        .iter()
        .filter(|d| d.id.starts_with("off"))
        .all(|d| d.params.exits() && d.params.exit_pos == cfg.geometry.merge_end_m));

    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    // demand window is 120 s; give stragglers time to clear the road
    sim.run(cfg.horizon_s + 120.0).unwrap();
    assert!(sim.total_spawned > 0);
    let exited = sim.total_exited as usize;
    assert!(
        exited as f32 >= 0.8 * n_off as f32,
        "only {exited} of {n_off} off-flow vehicles exited via the off-ramp"
    );
    assert!(
        exited <= n_off,
        "{exited} exits but only {n_off} off-flow vehicles"
    );
    assert!(sim.total_flow > 0.0, "through traffic still flows");

    // off_share = 0: the exit machinery stays perfectly silent
    let cfg0 = family.compile(&ramp_weave_point(&registry, 0.0)).unwrap();
    let routes0 = duarouter(&cfg0.network, &cfg0.flows, 17).unwrap();
    assert!(routes0.departures.iter().all(|d| !d.params.exits()));
    let mut sim0 = SumoSim::new(
        cfg0.geometry,
        cfg0.capacity,
        routes0,
        Box::new(NativeIdmStepper::new(cfg0.geometry, MobilParams::default())),
    );
    sim0.run(cfg0.horizon_s + 120.0).unwrap();
    assert_eq!(sim0.total_exited, 0.0);
    assert!(sim0.total_flow > 0.0);
}

/// Exit dynamics are part of the bit-exactness contract: the sweep
/// stepper and the O(N²) reference agree *exactly* on a ramp-weave
/// rollout with live exit traffic (observables incl. n_exited, state).
#[test]
fn ramp_weave_reference_and_native_steppers_agree_exactly_with_exits() {
    let registry = FamilyRegistry::builtin();
    let cfg = registry
        .get("ramp-weave")
        .unwrap()
        .compile(&ramp_weave_point(&registry, 0.25))
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 29).unwrap();

    let mut native = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes.clone(),
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let mut reference = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(ReferenceIdmStepper {
            scenario: cfg.geometry,
            mobil: MobilParams::default(),
        }),
    );
    for step in 0..600 {
        let a = native.step();
        let b = reference.step();
        assert_eq!(a, b, "observables diverged at step {step}");
        assert_eq!(native.traffic, reference.traffic, "state diverged at step {step}");
    }
    assert!(native.total_exited > 0.0, "exits occurred inside the window");
}

/// ISSUE 4 satellite: ring-shockwave conserves density — the unrolled
/// road now outruns the horizon, so the platoon packed by the burst is
/// still fully on the road at the end of the run (nobody retires at
/// road_end mid-horizon and kills the shockwave).
#[test]
fn ring_shockwave_conserves_density_after_burst() {
    use webots_hpc::scenario::RingShockwaveFamily;
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("ring-shockwave", &UniformSampler, 5, 2)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 2).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    let obs = sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 5, "burst packs the ring");
    // nothing ever retires inside the horizon...
    assert_eq!(sim.total_flow, 0.0, "a vehicle drained at road_end");
    assert_eq!(sim.total_exited, 0.0);
    // ...so once the burst window (+ insertion-queue slack) has passed,
    // the active count never decreases again
    let burst_steps =
        ((RingShockwaveFamily::BURST_S * 2.0) / cfg.geometry.dt_s.max(1e-6)) as usize;
    let after_burst = &obs[burst_steps.min(obs.len() - 1)..];
    let mut prev = 0.0f32;
    for (k, o) in after_burst.iter().enumerate() {
        assert!(
            o.n_active >= prev,
            "active count dropped after the burst (step {k}: {} < {prev})",
            o.n_active
        );
        prev = o.n_active;
    }
    assert!(
        obs.last().unwrap().n_active as u64 == sim.total_spawned,
        "everyone spawned is still circulating at the horizon"
    );
}

#[test]
fn ramp_weave_on_traffic_merges_before_weave_end() {
    let registry = FamilyRegistry::builtin();
    let (_, cfg) = registry
        .materialize("ramp-weave", &UniformSampler, 8, 3)
        .unwrap();
    let routes = duarouter(&cfg.network, &cfg.flows, 6).unwrap();
    let mut sim = SumoSim::new(
        cfg.geometry,
        cfg.capacity,
        routes,
        Box::new(NativeIdmStepper::new(cfg.geometry, MobilParams::default())),
    );
    sim.run(cfg.horizon_s).unwrap();
    assert!(sim.total_spawned > 0);
    assert!(sim.total_merged > 0.0, "auxiliary-lane traffic merged");
    // the off-ramp edge is part of the compiled graph
    assert!(cfg.network.edge("off_ramp").is_ok());
}
