//! Declare the custom `loom` cfg (set by scripts/check.sh's model-
//! checker lane via `RUSTFLAGS="--cfg loom"`) so the `unexpected_cfgs`
//! lint (rust 1.80+) stays quiet under `cargo clippy -- -D warnings`.
//! The manifest is supplied by the driver/CI (see .gitignore), so the
//! declaration can't live in `[lints.rust]` — a build script is the
//! only in-repo place to emit it.  On toolchains that predate
//! check-cfg the directive is ignored as unknown metadata, which is
//! exactly right: the lint doesn't exist there either.

fn main() {
    println!("cargo:rustc-check-cfg=cfg(loom)");
    println!("cargo:rerun-if-changed=build.rs");
}
