#!/usr/bin/env python3
"""Python mirror of `cargo run -p xtask -- lint` (rust/xtask).

Containers without a rust toolchain (see .claude/skills/verify/SKILL.md)
can still run the project lint: this mirror implements the same
tokenizer and the same rule semantics as the rust analyzer, over the
same config (xtask/src/config.rs) and the same allowlist
(rust/xtask/lint.allow).  The rust xtask is authoritative — check.sh
runs it, and its fixture self-tests pin the rule behavior; this mirror
exists so a toolchain-less session can (a) verify a change keeps the
tree lint-clean and (b) cross-check the analyzer's findings.

Usage:  python3 scripts/lint_mirror.py [--root rust/src] [-v]
        python3 scripts/lint_mirror.py --self-test
Exit 0 = clean, 1 = violations, 2 = internal/allowlist error.

--self-test lints the seeded fixtures in rust/xtask/fixtures/ under
their pretend paths and asserts the exact hit counts the rust xtask's
own tests pin — proving the mirror and the analyzer agree on the rule
semantics before trusting a "clean" verdict.
"""

import os
import re
import sys

# --------------------------------------------------------------------------
# configuration — MUST stay in sync with rust/xtask/src/config.rs
# --------------------------------------------------------------------------

# panic-freedom: deny .unwrap()/.expect() in every library module
# (main.rs is the CLI; test items are exempt at AST level).
PANIC_SKIP_FILES = {"main.rs"}

# indexing-panics: `expr[...]` is denied only in the concurrency-heavy
# control plane, where a panic aborts an unattended campaign; numeric
# hot-path modules (sumo/, runtime/ kernels) index slices pervasively
# and are covered by bounds-checked accessors + tests instead.
INDEXING_DIRS = ("fabric/", "pipeline/", "telemetry/")

# print-freedom: library observability goes through telemetry; stray
# prints vanish in batch campaigns.  main.rs is the CLI (printing is
# its job); harness/ and metrics/ are operator-facing table writers.
PRINT_SKIP_FILES = {"main.rs"}
PRINT_SKIP_DIRS = ("harness/", "metrics/",)
PRINT_MACROS = {"println", "eprintln", "print", "eprint", "dbg"}

# lock-discipline: while a guard from one of GUARD_CALLS is live, none
# of DENY_CALLS may be reached (blocking I/O, fsync, sleeps, nested
# locks, telemetry flushes — anything that can stall the dispatch
# mutex every worker connection and the reaper serialize on).
# telemetry/sink.rs is covered for its sink-registry RwLock (fan-out
# runs on an Arc snapshot, never under the lock); read/write as guard
# calls also lint the RwLock read→write upgrade deadlock.
LOCK_FILES = ("fabric/coordinator.rs", "telemetry/sink.rs")
GUARD_CALLS = {"lock", "read", "write"}   # `lock(&s)` helper, `.lock()`, `.read()`, `.write()`
DENY_UNDER_GUARD = {
    "sleep", "sync_all", "sync_data", "flush", "flush_all",
    "write_all", "write_msg", "supervise_instance", "publish_run_csv",
    "mark_running", "mark_completed", "mark_failed", "emit",
    "read", "read_line", "write", "assemble_aggregate", "plan_run",
    "lock_ledger",
}

# ledger-before-event: every telemetry emit of a LedgerTransition must
# be preceded (same fn body) by the durability fsync.  Only emit(...)
# argument positions count — LedgerTransition in match arms, parsers,
# and constructors elsewhere is fine.
LEDGER_EVENT = "LedgerTransition"
LEDGER_EMIT_CALLS = {"emit"}
LEDGER_SYNC_CALLS = {"sync_data", "sync_all"}

# deny-attribute presence: these module roots must keep the clippy gate.
DENY_ATTR_FILES = (
    "fabric/mod.rs", "pipeline/mod.rs", "telemetry/mod.rs",
    "runtime/mod.rs", "traci/mod.rs", "display/mod.rs",
)
DENY_ATTR = "deny(clippy::unwrap_used, clippy::expect_used)"

# --------------------------------------------------------------------------
# tokenizer (mirror of xtask/src/lexer.rs)
# --------------------------------------------------------------------------

IDENT_START = re.compile(r"[A-Za-z_]")
IDENT_CONT = re.compile(r"[A-Za-z0-9_]")


class Tok:
    __slots__ = ("kind", "text", "line")

    def __init__(self, kind, text, line):
        self.kind = kind      # 'ident' | 'punct' | 'lit'
        self.text = text
        self.line = line

    def __repr__(self):
        return f"{self.kind}:{self.text}@{self.line}"


def tokenize(src, path="<str>"):
    toks = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        if src.startswith("//", i):
            j = src.find("\n", i)
            i = n if j < 0 else j
            continue
        if src.startswith("/*", i):
            depth, i = 1, i + 2
            while i < n and depth:
                if src.startswith("/*", i):
                    depth += 1
                    i += 2
                elif src.startswith("*/", i):
                    depth -= 1
                    i += 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        # raw strings r"..." / r#"..."# / br#"..."#
        m = re.match(r'(b?r)(#*)"', src[i:])
        if m:
            hashes = m.group(2)
            close = '"' + hashes
            j = src.find(close, i + len(m.group(0)))
            if j < 0:
                raise SyntaxError(f"{path}:{line}: unterminated raw string")
            text = src[i : j + len(close)]
            line += text.count("\n")
            toks.append(Tok("lit", text, line))
            i = j + len(close)
            continue
        if c == '"' or src.startswith('b"', i):
            j = i + (2 if c == "b" else 1)
            start_line = line
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == "\n":
                    line += 1
                if src[j] == '"':
                    break
                j += 1
            if j >= n:
                raise SyntaxError(f"{path}:{start_line}: unterminated string")
            toks.append(Tok("lit", src[i : j + 1], start_line))
            i = j + 1
            continue
        if c == "'":
            # char literal vs lifetime: 'a' is a char, 'a is a lifetime
            m = re.match(r"'(\\.[^']*|[^'\\])'", src[i:])
            if m:
                toks.append(Tok("lit", m.group(0), line))
                i += len(m.group(0))
            else:
                m = re.match(r"'[A-Za-z_][A-Za-z0-9_]*", src[i:])
                if not m:
                    raise SyntaxError(f"{path}:{line}: stray quote")
                toks.append(Tok("punct", m.group(0), line))
                i += len(m.group(0))
            continue
        if IDENT_START.match(c):
            j = i + 1
            while j < n and IDENT_CONT.match(src[j]):
                j += 1
            toks.append(Tok("ident", src[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i + 1
            while j < n and (IDENT_CONT.match(src[j]) or src[j] == "."):
                # `0..10` range: stop the number before `..`
                if src[j] == "." and src.startswith("..", j):
                    break
                j += 1
            toks.append(Tok("lit", src[i:j], line))
            i = j
            continue
        toks.append(Tok("punct", c, line))
        i += 1
    return toks


# --------------------------------------------------------------------------
# test-item marking (mirror of xtask/src/items.rs)
# --------------------------------------------------------------------------

def _attr_end(toks, i):
    """toks[i] is '#'; return index one past the closing ']'."""
    j = i + 1
    if j < len(toks) and toks[j].text == "!":
        j += 1
    assert toks[j].text == "[", "attribute must open with ["
    depth = 0
    while j < len(toks):
        if toks[j].text == "[":
            depth += 1
        elif toks[j].text == "]":
            depth -= 1
            if depth == 0:
                return j + 1
        j += 1
    raise SyntaxError("unterminated attribute")


def _cfg_requires_test(toks, i, end):
    """True if the attribute tokens in [i, end) are a cfg(...) whose
    predicate evaluates FALSE when test=false — i.e. the item exists
    only in test builds.  Unknown predicates evaluate True
    (conservative: treated as compiled into the library)."""
    texts = [t.text for t in toks[i:end]]
    if "cfg" not in texts:
        return False
    k = texts.index("cfg")
    if k + 1 >= len(texts) or texts[k + 1] != "(":
        return False

    def parse(pos):
        # returns (value_when_not_test, next_pos)
        name = texts[pos]
        if name == "test":
            return False, pos + 1
        if name in ("any", "all", "not") and pos + 1 < len(texts) and texts[pos + 1] == "(":
            vals, p = [], pos + 2
            while texts[p] != ")":
                if texts[p] == ",":
                    p += 1
                    continue
                v, p = parse(p)
                vals.append(v)
            p += 1
            if name == "any":
                return any(vals), p
            if name == "all":
                return all(vals), p
            return (not vals[0]), p
        # feature = "...", target_os = "...", miri, loom, ... → unknown
        p = pos + 1
        while p < len(texts) and texts[p] not in (",", ")"):
            p += 1
        return True, p

    val, _ = parse(k + 2)
    return not val


def mark_test_tokens(toks):
    """Boolean per token: is this token inside a #[cfg(test)]-gated item
    (at any nesting depth)?  Attributes attach to the next item; an
    item's extent runs to its matching close brace or to `;`."""
    n = len(toks)
    in_test = [False] * n
    i = 0
    pending_test = False
    stack = []  # (close_needed_depth marker) entries: 'test' item depths
    depth = 0
    test_until_depth = None  # once set, tokens are test until depth drops below
    test_depths = []

    while i < n:
        t = toks[i]
        if t.text == "#" and t.kind == "punct" and i + 1 < n and toks[i + 1].text in ("[", "!"):
            end = _attr_end(toks, i)
            is_test = _cfg_requires_test(toks, i, end)
            inner = toks[i + 1].text == "!"
            if test_depths:
                for k in range(i, end):
                    in_test[k] = True
            if is_test and not inner:
                pending_test = True
                # the attribute tokens themselves are test-only too
                for k in range(i, end):
                    in_test[k] = True
            i = end
            continue
        if test_depths:
            in_test[i] = True
        if t.text == "{":
            depth += 1
            if pending_test:
                test_depths.append(depth)
                in_test[i] = True
                pending_test = False
        elif t.text == "}":
            if test_depths and depth == test_depths[-1]:
                test_depths.pop()
                in_test[i] = True
            depth -= 1
        elif t.text == ";" and pending_test and depth == (test_depths[-1] if test_depths else 0):
            # `#[cfg(test)] use foo;` — extent ended without a body
            pending_test = False
            in_test[i] = True
        i += 1
    return in_test


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

class Violation:
    def __init__(self, rule, path, line, msg):
        self.rule, self.path, self.line, self.msg = rule, path, line, msg

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def rule_panic_freedom(path, rel, toks, in_test, out):
    if os.path.basename(rel) in PANIC_SKIP_FILES:
        return
    n = len(toks)
    for i, t in enumerate(toks):
        if in_test[i]:
            continue
        if t.kind == "ident" and t.text in ("unwrap", "expect"):
            if i > 0 and toks[i - 1].text == "." and i + 1 < n and toks[i + 1].text == "(":
                out.append(Violation(
                    "panic-freedom", rel, t.line,
                    f".{t.text}() can panic in library code — return Result, "
                    "recover (unwrap_or_else), or allowlist with a justification"))
    if rel.startswith(INDEXING_DIRS):
        for i, t in enumerate(toks):
            if in_test[i] or t.text != "[" or i == 0:
                continue
            prev = toks[i - 1]
            # an index expression follows a value: ident, ), ] or literal.
            # `#[attr]`, array literals `= [`, `vec![`, types `[u8; 4]`
            # all follow punctuation or macro bangs instead.
            if prev.text == "!" or prev.kind == "punct" and prev.text not in (")", "]"):
                continue
            if prev.kind == "lit":
                continue
            if prev.kind == "ident" and prev.text in (
                    "return", "in", "break", "mut", "else", "match", "vec"):
                continue
            out.append(Violation(
                "panic-freedom", rel, t.line,
                "indexing can panic in control-plane code — use .get()/"
                ".get_mut() or allowlist with a bounds argument"))


def rule_print_freedom(path, rel, toks, in_test, out):
    if os.path.basename(rel) in PRINT_SKIP_FILES or rel.startswith(PRINT_SKIP_DIRS):
        return
    for i, t in enumerate(toks):
        if in_test[i]:
            continue
        if t.kind == "ident" and t.text in PRINT_MACROS:
            if i + 1 < len(toks) and toks[i + 1].text == "!":
                out.append(Violation(
                    "print-freedom", rel, t.line,
                    f"{t.text}! in library code — emit a telemetry event or "
                    "metric instead (stdout vanishes in batch campaigns)"))


def _call_name(toks, i):
    """If toks[i] opens a call `name(` or `.name(`, return name."""
    t = toks[i]
    if t.kind != "ident":
        return None
    if i + 1 < len(toks) and toks[i + 1].text == "(":
        return t.text
    return None


def rule_lock_discipline(path, rel, toks, in_test, out):
    if not rel.endswith(LOCK_FILES):
        return
    n = len(toks)

    # statement-level scan with a scope stack of live guards
    guards = []  # list of (name_or_None, depth, acquired_line); None = temporary
    depth = 0
    i = 0
    stmt_has_let = False
    let_name = None
    stmt_acquired = None   # guard acquired in the current statement
    pending_temp = []      # temporary guards live to end of statement

    def deny_check(idx):
        name = _call_name(toks, idx)
        if name in DENY_UNDER_GUARD and (guards or pending_temp or stmt_acquired):
            hold = guards[-1][0] if guards else "<temporary>"
            out.append(Violation(
                "lock-discipline", rel, toks[idx].line,
                f"`{name}(...)` while guard `{hold}` from lock() is live — "
                "release the dispatch mutex before blocking work"))

    while i < n:
        t = toks[i]
        if in_test[i]:
            i += 1
            continue
        if t.text == "{":
            depth += 1
            if stmt_acquired is not None:
                # `match lock(&x) { ... }` / `if let ... = lock(&x) {`:
                # the temporary lives for the attached block
                pending_temp.append((stmt_acquired, depth))
                stmt_acquired = None
            stmt_has_let, let_name = False, None
            i += 1
            continue
        if t.text == "}":
            guards = [g for g in guards if g[1] < depth]
            pending_temp = [g for g in pending_temp if g[1] < depth]
            # a tail-expression temporary (`fn f() { x.lock() }`) dies
            # with its block
            stmt_acquired = None
            depth -= 1
            i += 1
            continue
        if t.text == ";":
            if stmt_acquired is not None and stmt_has_let and let_name not in (None, "_"):
                guards.append((let_name, depth, stmt_acquired))
            stmt_has_let, let_name, stmt_acquired = False, None, None
            i += 1
            continue
        if t.kind == "ident" and t.text == "let":
            stmt_has_let = True
            # pattern: let [mut] NAME =
            j = i + 1
            if j < n and toks[j].text == "mut":
                j += 1
            if j < n and toks[j].kind == "ident":
                let_name = toks[j].text
            i += 1
            continue
        if t.kind == "ident" and t.text == "drop" and i + 1 < n and toks[i + 1].text == "(":
            if i + 2 < n and toks[i + 2].kind == "ident":
                victim = toks[i + 2].text
                guards = [g for g in guards if g[0] != victim]
            i += 1
            continue
        name = _call_name(toks, i)
        if name in GUARD_CALLS:
            prev_dot = i > 0 and toks[i - 1].text == "."
            if name == "lock" or prev_dot:
                deny_check(i)  # nested acquisition under a live guard
                stmt_acquired = t.line
                i += 1
                continue
        deny_check(i)
        i += 1


def rule_ledger_order(path, rel, toks, in_test, out):
    n = len(toks)
    # find fn bodies containing LedgerTransition; require a preceding
    # sync_data/sync_all call inside the same body
    i = 0
    while i < n:
        if toks[i].kind == "ident" and toks[i].text == "fn" and not in_test[i]:
            # find body open brace
            j = i + 1
            while j < n and toks[j].text not in ("{", ";"):
                j += 1
            if j >= n or toks[j].text == ";":
                i = j + 1
                continue
            depth, k = 1, j + 1
            synced_at = None
            while k < n and depth:
                tk = toks[k]
                if tk.text == "{":
                    depth += 1
                elif tk.text == "}":
                    depth -= 1
                elif tk.kind == "ident" and tk.text in LEDGER_SYNC_CALLS:
                    synced_at = k
                elif (tk.kind == "ident" and tk.text in LEDGER_EMIT_CALLS
                        and k + 1 < n and toks[k + 1].text == "("):
                    # scan the emit(...) argument list for the event kind
                    pdepth, m = 1, k + 2
                    hit = None
                    while m < n and pdepth:
                        if toks[m].text == "(":
                            pdepth += 1
                        elif toks[m].text == ")":
                            pdepth -= 1
                        elif toks[m].kind == "ident" and toks[m].text == LEDGER_EVENT:
                            hit = toks[m]
                        m += 1
                    if hit is not None and synced_at is None:
                        out.append(Violation(
                            "ledger-before-event", rel, hit.line,
                            "LedgerTransition emitted with no preceding "
                            "fsync in this fn — events must never lead the "
                            "durable ledger (events ⊇ ledger contract)"))
                    k = m - 1
                k += 1
            i = k
            continue
        i += 1


def rule_deny_attr(root, out):
    for rel in DENY_ATTR_FILES:
        p = os.path.join(root, rel)
        if not os.path.exists(p):
            out.append(Violation("deny-attr", rel, 0, "module root missing"))
            continue
        with open(p, encoding="utf-8") as f:
            if DENY_ATTR not in f.read():
                out.append(Violation(
                    "deny-attr", rel, 1,
                    f"module root lost its `#![{DENY_ATTR}]` gate"))


# --------------------------------------------------------------------------
# allowlist
# --------------------------------------------------------------------------

def load_allowlist(path):
    entries = []
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as f:
        for ln, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split(None, 2)
            if len(parts) != 3:
                print(f"allowlist:{ln}: need `rule path-suffix line-substring`",
                      file=sys.stderr)
                sys.exit(2)
            entries.append({"rule": parts[0], "suffix": parts[1],
                            "substr": parts[2], "used": False, "ln": ln})
    return entries


def apply_allowlist(violations, entries, src_lines):
    kept = []
    for v in violations:
        line_text = ""
        lines = src_lines.get(v.path)
        if lines and 1 <= v.line <= len(lines):
            line_text = lines[v.line - 1]
        hit = None
        for e in entries:
            if e["rule"] == v.rule and v.path.endswith(e["suffix"]) \
                    and e["substr"] in line_text:
                hit = e
                break
        if hit:
            hit["used"] = True
        else:
            kept.append(v)
    return kept


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def lint_tree(root, allow_path, verbose=False):
    violations = []
    src_lines = {}
    for dirpath, _dirnames, filenames in os.walk(root):
        for fname in sorted(filenames):
            if not fname.endswith(".rs"):
                continue
            path = os.path.join(dirpath, fname)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, encoding="utf-8") as f:
                src = f.read()
            src_lines[rel] = src.splitlines()
            toks = tokenize(src, rel)
            in_test = mark_test_tokens(toks)
            rule_panic_freedom(path, rel, toks, in_test, violations)
            rule_print_freedom(path, rel, toks, in_test, violations)
            rule_lock_discipline(path, rel, toks, in_test, violations)
            rule_ledger_order(path, rel, toks, in_test, violations)
    rule_deny_attr(root, violations)

    entries = load_allowlist(allow_path)
    violations = apply_allowlist(violations, entries, src_lines)
    stale = [e for e in entries if not e["used"]]
    return violations, stale


def lint_source(rel, src):
    """Run the per-file rules over one source string (self-test helper)."""
    toks = tokenize(src, rel)
    in_test = mark_test_tokens(toks)
    out = []
    rule_panic_freedom(rel, rel, toks, in_test, out)
    rule_print_freedom(rel, rel, toks, in_test, out)
    rule_lock_discipline(rel, rel, toks, in_test, out)
    rule_ledger_order(rel, rel, toks, in_test, out)
    return out


def self_test():
    """Lint the seeded fixtures; assert the exact counts the rust
    xtask's unit tests pin.  Any drift = the mirror lies."""
    fixdir = os.path.join("rust", "xtask", "fixtures")
    # fixture file → (pretend rel path, rule, expected hit count)
    cases = [
        ("seeded_panic.rs", "pipeline/seeded.rs", "panic-freedom", 3),
        ("seeded_print.rs", "telemetry/seeded.rs", "print-freedom", 3),
        ("seeded_lock.rs", "fabric/coordinator.rs", "lock-discipline", 4),
        ("seeded_sink.rs", "telemetry/sink.rs", "lock-discipline", 3),
        ("seeded_ledger.rs", "telemetry/seeded.rs", "ledger-before-event", 1),
    ]
    failures = 0
    for fname, rel, rule, want in cases:
        path = os.path.join(fixdir, fname)
        with open(path, encoding="utf-8") as f:
            src = f.read()
        hits = [v for v in lint_source(rel, src) if v.rule == rule]
        status = "ok" if len(hits) == want else "FAIL"
        print(f"self-test {fname:18s} [{rule}] want {want} got {len(hits)}  {status}")
        if len(hits) != want:
            for v in hits:
                print(f"  {v}", file=sys.stderr)
            failures += 1
    # the post-test-mod print (the old awk gate's hole) must be among
    # the print hits
    with open(os.path.join(fixdir, "seeded_print.rs"), encoding="utf-8") as f:
        prints = [v for v in lint_source("telemetry/seeded.rs", f.read())
                  if v.rule == "print-freedom"]
    if not any(v.line > 20 for v in prints):
        print("self-test seeded_print.rs: post-test-mod library print NOT "
              "caught — awk-gate hole is back", file=sys.stderr)
        failures += 1
    if failures:
        print(f"\nlint_mirror self-test: {failures} case(s) FAILED", file=sys.stderr)
        return 1
    print("lint_mirror self-test: all cases pass")
    return 0


def main():
    root = "rust/src"
    allow = "rust/xtask/lint.allow"
    verbose = "-v" in sys.argv
    args = [a for a in sys.argv[1:] if a != "-v"]
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if "--self-test" in args:
        os.chdir(repo)
        return self_test()
    if "--root" in args:
        root = args[args.index("--root") + 1]
    os.chdir(repo)

    violations, stale = lint_tree(root, allow, verbose)
    for v in violations:
        print(v)
    for e in stale:
        print(f"lint.allow:{e['ln']}: stale allowlist entry "
              f"({e['rule']} {e['suffix']} {e['substr']!r}) matched nothing",
              file=sys.stderr)
    if violations or stale:
        print(f"\nlint_mirror: {len(violations)} violation(s), "
              f"{len(stale)} stale allowlist entr(ies)", file=sys.stderr)
        return 1
    print("lint_mirror: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
