#!/usr/bin/env python3
"""Manifest-schema gate: geometry-generic, destination-aware artifacts
must carry the operand AND column layouts the rust runtime expects.

The contract lives in three places that can silently drift apart:

  * ``python/compile/model.py`` — ``GEOM_COLUMNS`` / ``PARAM_COLUMNS``
    / ``OBS_COLUMNS`` (what the lowered executables actually consume),
  * ``artifacts/manifest.json`` — the recorded layouts + per-entry
    ``operands`` (what the compile path recorded),
  * ``rust/src/runtime/manifest.rs`` — ``GEOMETRY_COLUMNS`` /
    ``PARAM_COLUMNS`` / ``OBS_COLUMNS`` (what the runtime feeds the
    executables and how it reads them back).

Schema 3 adds the per-vehicle destination columns (``exit_pos``,
``exit_flag``) and the ``n_exited`` observable; schema 4 adds the fused
K-step rollout entry points (``rollout{K}_{N}`` / ``rolloutb{K}_{N}``
over the ``ROLLOUT_STEPS`` K ladder); schema 5 adds the device-resident
whole-run entry points (``run{T}_{N}`` / ``runb{T}_{N}`` over the
``RUN_STEPS`` total-steps ladder) whose demand arrives as a compiled-in
departure-table operand (``departure_columns`` × ``departure_rows``).
The gate pins the per-column layout on all three sides, the bucket
ladder (``aot.py BUCKETS`` vs ``family.rs DEFAULT_BUCKET_LADDER``), the
rollout K ladder (``aot.py ROLLOUT_STEPS`` vs ``manifest.rs
ROLLOUT_LADDER`` vs the lowered artifacts), and the run T ladder +
departure-row layout (``aot.py RUN_STEPS``/``model.py DEP_COLUMNS`` vs
``manifest.rs RUN_LADDER``/``DEPARTURE_COLUMNS`` vs the artifacts), and
fails loudly on any mismatch.  With no ``artifacts/`` directory it still
checks the source-side layouts (so the gate is meaningful on build
machines that haven't lowered artifacts).  Run from anywhere inside the
repo; wired into ``scripts/check.sh``.
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

#: the rust-side ABI (sumo/state.rs G_*/P_* order) — the single source
#: of truth this gate pins everything else to.
EXPECTED_GEOMETRY_COLUMNS = ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"]
EXPECTED_PARAM_COLUMNS = ["v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag"]
EXPECTED_OBS_COLUMNS = ["n_active", "mean_speed", "flow", "n_merged", "n_exited"]
EXPECTED_SCHEMA = 5
#: the lowered bucket ladder (aot.py BUCKETS) — family.rs suggests
#: capacities from the same ladder so no point falls back to native.
EXPECTED_BUCKETS = [16, 64, 256, 1024]
#: the fused-rollout K ladder (aot.py ROLLOUT_STEPS == manifest.rs
#: ROLLOUT_LADDER) and the entry-name stems the runtime resolves.
EXPECTED_ROLLOUT_STEPS = [1, 8, 32]
EXPECTED_ROLLOUT_ENTRY_POINTS = ["rollout", "rolloutb"]
#: the whole-run T ladder (aot.py RUN_STEPS == manifest.rs RUN_LADDER),
#: its entry stems, and the departure-table operand layout (model.py
#: DEP_COLUMNS == manifest.rs DEPARTURE_COLUMNS; rows = aot.py
#: DEPARTURE_ROWS) — schema 5.
EXPECTED_RUN_STEPS = [200, 1200, 1800]
EXPECTED_RUN_ENTRY_POINTS = ["run", "runb"]
EXPECTED_DEPARTURE_COLUMNS = [
    "step", "x", "v", "lane",
    "v0", "T", "a_max", "b", "s0", "length", "exit_pos", "exit_flag",
]
EXPECTED_DEPARTURE_ROWS = 256
#: operand counts per artifact kind (step/stepb/rollout* carry the
#: geometry operand; run* additionally carry the departure table).
EXPECTED_OPERANDS = {
    "step": 3, "stepb": 3, "rollout": 3, "rolloutb": 3,
    "run": 4, "runb": 4, "idm": 2, "radar": 1,
}

REPO = pathlib.Path(__file__).resolve().parents[1]


def fail(msg: str) -> None:
    print(f"check_manifest: FAIL: {msg}")
    sys.exit(1)


def pinned_list(text: str, name: str, where: str, quote: str = '"') -> list:
    """Extract `NAME = [ ... ]` string entries, textually (no imports)."""
    m = re.search(rf"{name}[^=\n]*=\s*\[([^\]]*)\]", text)
    if not m:
        fail(f"{where} defines no {name}")
    return re.findall(rf'{quote}([^{quote}]+){quote}', m.group(1))


def check_model_py() -> None:
    """model.py column layouts must match, parsed textually so this gate
    needs no jax import."""
    text = (REPO / "python" / "compile" / "model.py").read_text()
    for name, want in (
        ("GEOM_COLUMNS", EXPECTED_GEOMETRY_COLUMNS),
        ("PARAM_COLUMNS", EXPECTED_PARAM_COLUMNS),
        ("OBS_COLUMNS", EXPECTED_OBS_COLUMNS),
        ("DEP_COLUMNS", EXPECTED_DEPARTURE_COLUMNS),
    ):
        cols = pinned_list(text, name, "python/compile/model.py")
        if cols != want:
            fail(f"model.py {name} {cols} != {want}")
    # the table's spawn/param columns must be the state tail + the full
    # schema-3 params row, in order — the kernel copies them verbatim
    if EXPECTED_DEPARTURE_COLUMNS[1:4] != ["x", "v", "lane"]:
        fail("DEP_COLUMNS spawn columns must be [x, v, lane]")
    if EXPECTED_DEPARTURE_COLUMNS[4:] != EXPECTED_PARAM_COLUMNS:
        fail("DEP_COLUMNS param tail must equal PARAM_COLUMNS")


def check_aot_py() -> None:
    """aot.py BUCKETS must match the ladder family.rs suggests from,
    and ROLLOUT_STEPS the K ladder manifest.rs/the runtime expect."""
    text = (REPO / "python" / "compile" / "aot.py").read_text()
    m = re.search(r"^BUCKETS\s*=\s*\(([^)]*)\)", text, re.M)
    if not m:
        fail("python/compile/aot.py defines no BUCKETS")
    buckets = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if buckets != EXPECTED_BUCKETS:
        fail(f"aot.py BUCKETS {buckets} != {EXPECTED_BUCKETS}")
    m = re.search(r"^ROLLOUT_STEPS\s*=\s*\(([^)]*)\)", text, re.M)
    if not m:
        fail("python/compile/aot.py defines no ROLLOUT_STEPS")
    steps = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if steps != EXPECTED_ROLLOUT_STEPS:
        fail(f"aot.py ROLLOUT_STEPS {steps} != {EXPECTED_ROLLOUT_STEPS}")
    m = re.search(r"^RUN_STEPS\s*=\s*\(([^)]*)\)", text, re.M)
    if not m:
        fail("python/compile/aot.py defines no RUN_STEPS")
    steps = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if steps != EXPECTED_RUN_STEPS:
        fail(f"aot.py RUN_STEPS {steps} != {EXPECTED_RUN_STEPS}")
    m = re.search(r"^DEPARTURE_ROWS\s*=\s*(\d+)", text, re.M)
    if not m:
        fail("python/compile/aot.py defines no DEPARTURE_ROWS")
    if int(m.group(1)) != EXPECTED_DEPARTURE_ROWS:
        fail(f"aot.py DEPARTURE_ROWS {m.group(1)} != {EXPECTED_DEPARTURE_ROWS}")


def check_family_rs() -> None:
    text = (REPO / "rust" / "src" / "scenario" / "family.rs").read_text()
    m = re.search(r"DEFAULT_BUCKET_LADDER[^=]*=\s*\[([^\]]*)\]", text)
    if not m:
        fail("rust/src/scenario/family.rs defines no DEFAULT_BUCKET_LADDER")
    ladder = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if ladder != EXPECTED_BUCKETS:
        fail(f"family.rs DEFAULT_BUCKET_LADDER {ladder} != {EXPECTED_BUCKETS}")


def check_manifest_rs() -> None:
    text = (REPO / "rust" / "src" / "runtime" / "manifest.rs").read_text()
    for name, want in (
        ("GEOMETRY_COLUMNS", EXPECTED_GEOMETRY_COLUMNS),
        ("PARAM_COLUMNS", EXPECTED_PARAM_COLUMNS),
        ("OBS_COLUMNS", EXPECTED_OBS_COLUMNS),
        ("ROLLOUT_ENTRY_POINTS", EXPECTED_ROLLOUT_ENTRY_POINTS),
        ("DEPARTURE_COLUMNS", EXPECTED_DEPARTURE_COLUMNS),
        ("RUN_ENTRY_POINTS", EXPECTED_RUN_ENTRY_POINTS),
    ):
        cols = pinned_list(text, name, "rust/src/runtime/manifest.rs")
        if cols != want:
            fail(f"manifest.rs {name} {cols} != {want}")
    m = re.search(r"ROLLOUT_LADDER[^=]*=\s*\[([^\]]*)\]", text)
    if not m:
        fail("rust/src/runtime/manifest.rs defines no ROLLOUT_LADDER")
    ladder = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if ladder != EXPECTED_ROLLOUT_STEPS:
        fail(f"manifest.rs ROLLOUT_LADDER {ladder} != {EXPECTED_ROLLOUT_STEPS}")
    m = re.search(r"\bRUN_LADDER[^=]*=\s*\[([^\]]*)\]", text)
    if not m:
        fail("rust/src/runtime/manifest.rs defines no RUN_LADDER")
    ladder = [int(v) for v in re.findall(r"\d+", m.group(1))]
    if ladder != EXPECTED_RUN_STEPS:
        fail(f"manifest.rs RUN_LADDER {ladder} != {EXPECTED_RUN_STEPS}")


def check_artifacts() -> bool:
    """Validate artifacts/manifest.json when present.  Returns whether a
    manifest was found."""
    path = REPO / "artifacts" / "manifest.json"
    if not path.exists():
        return False
    manifest = json.loads(path.read_text())
    if manifest.get("format") != "hlo-text":
        fail(f"unexpected artifact format {manifest.get('format')!r}")
    if manifest.get("schema") != EXPECTED_SCHEMA:
        fail(
            f"artifacts are schema {manifest.get('schema')!r}, need {EXPECTED_SCHEMA} "
            "(geometry-generic); re-run `make artifacts`"
        )
    if manifest.get("geometry_columns") != EXPECTED_GEOMETRY_COLUMNS:
        fail(
            f"manifest geometry_columns {manifest.get('geometry_columns')} "
            f"!= {EXPECTED_GEOMETRY_COLUMNS}"
        )
    if manifest.get("param_columns") != EXPECTED_PARAM_COLUMNS:
        fail(
            f"manifest param_columns {manifest.get('param_columns')} "
            f"!= {EXPECTED_PARAM_COLUMNS} (schema-3 destination columns)"
        )
    if manifest.get("obs_columns") != EXPECTED_OBS_COLUMNS:
        fail(
            f"manifest obs_columns {manifest.get('obs_columns')} "
            f"!= {EXPECTED_OBS_COLUMNS}"
        )
    if sorted(manifest.get("buckets", [])) != EXPECTED_BUCKETS:
        fail(
            f"manifest buckets {manifest.get('buckets')} != {EXPECTED_BUCKETS} "
            "(stale/partial lowering breaks the zero-native-fallback ladder); "
            "re-run `make artifacts`"
        )
    if manifest.get("rollout_steps") != EXPECTED_ROLLOUT_STEPS:
        fail(
            f"manifest rollout_steps {manifest.get('rollout_steps')} "
            f"!= {EXPECTED_ROLLOUT_STEPS}; re-run `make artifacts`"
        )
    if manifest.get("rollout_entry_points") != EXPECTED_ROLLOUT_ENTRY_POINTS:
        fail(
            f"manifest rollout_entry_points {manifest.get('rollout_entry_points')} "
            f"!= {EXPECTED_ROLLOUT_ENTRY_POINTS}"
        )
    if manifest.get("run_steps") != EXPECTED_RUN_STEPS:
        fail(
            f"manifest run_steps {manifest.get('run_steps')} "
            f"!= {EXPECTED_RUN_STEPS}; re-run `make artifacts`"
        )
    if manifest.get("run_entry_points") != EXPECTED_RUN_ENTRY_POINTS:
        fail(
            f"manifest run_entry_points {manifest.get('run_entry_points')} "
            f"!= {EXPECTED_RUN_ENTRY_POINTS}"
        )
    if manifest.get("departure_columns") != EXPECTED_DEPARTURE_COLUMNS:
        fail(
            f"manifest departure_columns {manifest.get('departure_columns')} "
            f"!= {EXPECTED_DEPARTURE_COLUMNS} (schema-5 table layout)"
        )
    if manifest.get("departure_rows") != EXPECTED_DEPARTURE_ROWS:
        fail(
            f"manifest departure_rows {manifest.get('departure_rows')} "
            f"!= {EXPECTED_DEPARTURE_ROWS}"
        )
    buckets = set(manifest.get("buckets", []))
    seen_ns = set()
    seen_rollouts = set()
    seen_runs = set()
    for key, entry in manifest.get("entries", {}).items():
        kind, _, n = key.rpartition("_")
        k = None
        t = None
        # longest stem first so 'rolloutb8' doesn't parse as 'rollout'+'b8'
        # (and 'runb200' not as 'run'+'b200')
        if kind.startswith("rolloutb"):
            stem, k = "rolloutb", int(kind[len("rolloutb"):])
            kind = "rolloutb"
        elif kind.startswith("rollout"):
            stem, k = "rollout", int(kind[len("rollout"):])
            kind = "rollout"
        elif kind.startswith("runb"):
            stem, t = "runb", int(kind[len("runb"):])
            kind = "runb"
        elif kind.startswith("run"):
            stem, t = "run", int(kind[len("run"):])
            kind = "run"
        if kind not in EXPECTED_OPERANDS:
            continue
        if entry.get("operands") != EXPECTED_OPERANDS[kind]:
            fail(
                f"entry '{key}' records {entry.get('operands')!r} operands, "
                f"expected {EXPECTED_OPERANDS[kind]}"
            )
        if entry.get("n") != int(n):
            fail(f"entry '{key}' bucket field {entry.get('n')} != key suffix {n}")
        if k is not None:
            if k not in EXPECTED_ROLLOUT_STEPS:
                fail(f"entry '{key}' uses K={k} outside the ladder {EXPECTED_ROLLOUT_STEPS}")
            if entry.get("k") != k:
                fail(f"entry '{key}' k field {entry.get('k')} != key K {k}")
            if entry.get("outputs") != 2:
                fail(f"rollout entry '{key}' must have 2 outputs (state, obs trace)")
            seen_rollouts.add((stem, k, entry["n"]))
        if t is not None:
            if t not in EXPECTED_RUN_STEPS:
                fail(f"entry '{key}' uses T={t} outside the ladder {EXPECTED_RUN_STEPS}")
            if entry.get("k_total") != t:
                fail(f"entry '{key}' k_total field {entry.get('k_total')} != key T {t}")
            if entry.get("outputs") != 4:
                fail(
                    f"run entry '{key}' must have 4 outputs "
                    "(state, params, obs trace, inserted mask)"
                )
            seen_runs.add((stem, t, entry["n"]))
        seen_ns.add(entry["n"])
        if not (REPO / "artifacts" / entry["file"]).exists():
            fail(f"entry '{key}' points at missing file {entry['file']}")
    if seen_ns != buckets:
        fail(f"entries cover buckets {sorted(seen_ns)} but manifest lists {sorted(buckets)}")
    want_rollouts = {
        (stem, k, n)
        for stem in EXPECTED_ROLLOUT_ENTRY_POINTS
        for k in EXPECTED_ROLLOUT_STEPS
        for n in EXPECTED_BUCKETS
    }
    if seen_rollouts != want_rollouts:
        missing = sorted(want_rollouts - seen_rollouts)
        fail(f"rollout entries missing for {missing}; re-run `make artifacts`")
    want_runs = {
        (stem, t, n)
        for stem in EXPECTED_RUN_ENTRY_POINTS
        for t in EXPECTED_RUN_STEPS
        for n in EXPECTED_BUCKETS
    }
    if seen_runs != want_runs:
        missing = sorted(want_runs - seen_runs)
        fail(f"run entries missing for {missing}; re-run `make artifacts`")
    return True


def main() -> None:
    check_model_py()
    check_aot_py()
    check_family_rs()
    check_manifest_rs()
    had_artifacts = check_artifacts()
    where = (
        "model.py + aot.py + family.rs + manifest.rs + artifacts/manifest.json"
        if had_artifacts
        else "model.py + aot.py + family.rs + manifest.rs (no artifacts/ lowered here)"
    )
    print(f"check_manifest: OK (schema {EXPECTED_SCHEMA}; {where})")


if __name__ == "__main__":
    main()
