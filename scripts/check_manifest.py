#!/usr/bin/env python3
"""Manifest-schema gate: geometry-generic artifacts must carry the
operand layout the rust runtime expects.

The contract lives in three places that can silently drift apart:

  * ``python/compile/model.py`` — ``GEOM_COLUMNS`` (what the lowered
    executables actually consume),
  * ``artifacts/manifest.json`` — ``geometry_columns`` + per-entry
    ``operands`` (what the compile path recorded),
  * ``rust/src/runtime/manifest.rs`` — ``GEOMETRY_COLUMNS`` (what the
    runtime feeds the executables).

This script pins all three to the layout below and fails loudly on any
mismatch.  With no ``artifacts/`` directory it still checks the two
source-side layouts (so the gate is meaningful on build machines that
haven't lowered artifacts).  Run from anywhere inside the repo; wired
into ``scripts/check.sh``.
"""

from __future__ import annotations

import json
import pathlib
import re
import sys

#: the rust-side ABI (sumo/state.rs G_* order) — the single source of
#: truth this gate pins everything else to.
EXPECTED_GEOMETRY_COLUMNS = ["road_end", "merge_start", "merge_end", "num_main_lanes", "dt"]
EXPECTED_SCHEMA = 2
#: operand counts per artifact kind (step/stepb carry the geometry).
EXPECTED_OPERANDS = {"step": 3, "stepb": 3, "idm": 2, "radar": 1}

REPO = pathlib.Path(__file__).resolve().parents[1]


def fail(msg: str) -> None:
    print(f"check_manifest: FAIL: {msg}")
    sys.exit(1)


def check_model_py() -> None:
    """model.GEOM_COLUMNS must match, parsed textually so this gate needs
    no jax import."""
    text = (REPO / "python" / "compile" / "model.py").read_text()
    m = re.search(r"GEOM_COLUMNS\s*=\s*\[([^\]]*)\]", text)
    if not m:
        fail("python/compile/model.py defines no GEOM_COLUMNS")
    cols = re.findall(r'"([^"]+)"', m.group(1))
    if cols != EXPECTED_GEOMETRY_COLUMNS:
        fail(f"model.py GEOM_COLUMNS {cols} != {EXPECTED_GEOMETRY_COLUMNS}")


def check_manifest_rs() -> None:
    text = (REPO / "rust" / "src" / "runtime" / "manifest.rs").read_text()
    m = re.search(r"GEOMETRY_COLUMNS[^=]*=\s*\[([^\]]*)\]", text)
    if not m:
        fail("rust/src/runtime/manifest.rs defines no GEOMETRY_COLUMNS")
    cols = re.findall(r'"([^"]+)"', m.group(1))
    if cols != EXPECTED_GEOMETRY_COLUMNS:
        fail(f"manifest.rs GEOMETRY_COLUMNS {cols} != {EXPECTED_GEOMETRY_COLUMNS}")


def check_artifacts() -> bool:
    """Validate artifacts/manifest.json when present.  Returns whether a
    manifest was found."""
    path = REPO / "artifacts" / "manifest.json"
    if not path.exists():
        return False
    manifest = json.loads(path.read_text())
    if manifest.get("format") != "hlo-text":
        fail(f"unexpected artifact format {manifest.get('format')!r}")
    if manifest.get("schema") != EXPECTED_SCHEMA:
        fail(
            f"artifacts are schema {manifest.get('schema')!r}, need {EXPECTED_SCHEMA} "
            "(geometry-generic); re-run `make artifacts`"
        )
    if manifest.get("geometry_columns") != EXPECTED_GEOMETRY_COLUMNS:
        fail(
            f"manifest geometry_columns {manifest.get('geometry_columns')} "
            f"!= {EXPECTED_GEOMETRY_COLUMNS}"
        )
    buckets = set(manifest.get("buckets", []))
    seen_ns = set()
    for key, entry in manifest.get("entries", {}).items():
        kind, _, n = key.rpartition("_")
        if kind not in EXPECTED_OPERANDS:
            continue
        if entry.get("operands") != EXPECTED_OPERANDS[kind]:
            fail(
                f"entry '{key}' records {entry.get('operands')!r} operands, "
                f"expected {EXPECTED_OPERANDS[kind]}"
            )
        if entry.get("n") != int(n):
            fail(f"entry '{key}' bucket field {entry.get('n')} != key suffix {n}")
        seen_ns.add(entry["n"])
        if not (REPO / "artifacts" / entry["file"]).exists():
            fail(f"entry '{key}' points at missing file {entry['file']}")
    if seen_ns != buckets:
        fail(f"entries cover buckets {sorted(seen_ns)} but manifest lists {sorted(buckets)}")
    return True


def main() -> None:
    check_model_py()
    check_manifest_rs()
    had_artifacts = check_artifacts()
    where = "model.py + manifest.rs + artifacts/manifest.json" if had_artifacts else (
        "model.py + manifest.rs (no artifacts/ lowered here)"
    )
    print(f"check_manifest: OK ({where})")


if __name__ == "__main__":
    main()
