#!/usr/bin/env python3
"""Pre-flight oracle for the rust sorted-sweep neighbor index (PR 1).

Mirrors, in numpy float32, both neighbor-scan algorithms used by the
native stepper:

  * the O(N^2) reference scans (``leader_scan`` / ``lane_gap_scan`` in
    ``rust/src/sumo/{idm,mobil}.rs``, themselves line-for-line ports of
    ``python/compile/kernels/ref.py``), and
  * the O(N log N) sorted-sweep versions (``rust/src/sumo/sweep.rs``):
    sort active slots by x once per lane per step, then find neighbors
    by partition point and resolve mask-min ties over the contiguous
    equal-dx run.

and asserts they are *bit-exact* (same gap, same mask-min tie-broken
speed/length selection, same exists flags) across randomized traffic:
varying fill, exact co-located ties, multiple lanes, N in {64, 256}.

It also times the two accel passes to estimate the algorithmic speedup
recorded in ``BENCH_runtime_hotpath.json`` (clearly labelled as a
python-mirror estimate there; re-measure with
``cargo bench --bench runtime_hotpath`` on a machine with the rust
toolchain).

Run: ``python3 scripts/validate_sweep.py``
"""

import time

import numpy as np

F = np.float32
FREE_GAP = F(1.0e6)
EPS = F(1e-6)


# ---------------------------------------------------------------- reference
def leader_scan_ref(x, v, lane, act, plen, i):
    """Port of rust `leader_scan` (O(N) per ego)."""
    xi = x[i]
    li = lane[i]
    center = FREE_GAP
    n = len(x)
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx < center:
            center = dx
    if center >= FREE_GAP * F(0.5):
        return FREE_GAP, v[i], False
    lv = FREE_GAP
    llen = FREE_GAP
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx <= center:
            lv = min(lv, v[j])
            llen = min(llen, plen[j])
    return F(center - llen), lv, True


def lane_gap_scan_ref(x, v, lane, act, plen, i, target):
    """Port of rust `lane_gap_scan` (O(N) per ego/target)."""
    xi = x[i]
    n = len(x)
    lead_center = FREE_GAP
    lag_center = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS:
            lead_center = min(lead_center, dx)
        elif dx < -EPS:
            lag_center = min(lag_center, F(-dx))
    lead_v = FREE_GAP
    lead_len = FREE_GAP
    lag_v = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS and dx <= lead_center:
            lead_v = min(lead_v, v[j])
            lead_len = min(lead_len, plen[j])
        elif dx < -EPS and F(-dx) <= lag_center:
            lag_v = min(lag_v, v[j])
    lead_has = lead_center < FREE_GAP * F(0.5)
    lag_has = lag_center < FREE_GAP * F(0.5)
    return (
        F(lead_center - lead_len) if lead_has else FREE_GAP,
        lead_v if lead_has else v[i],
        F(lag_center - plen[i]) if lag_has else FREE_GAP,
        lag_v if lag_has else v[i],
    )


# ------------------------------------------------------------- sorted sweep
class LaneIndex:
    """Port of rust `sweep::LaneIndex`."""

    def __init__(self, x, v, lane, act, plen):
        self.x, self.v, self.plen = x, v, plen
        self.groups = {}  # lane key -> list[(x, slot)] sorted by x
        for i in range(len(x)):
            if not act[i]:
                continue
            key = int(round(float(lane[i])))
            self.groups.setdefault(key, []).append((x[i], i))
        for g in self.groups.values():
            g.sort(key=lambda e: float(e[0]))

    def _group(self, target):
        return self.groups.get(int(round(float(target))), [])

    def scan_ahead(self, target, xi):
        """(center, mask-min v, mask-min len) among dx > EPS; FREE if none."""
        s = self._group(target)
        # partition point: first index with x - xi > EPS
        lo, hi = 0, len(s)
        while lo < hi:
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) <= EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == len(s):
            return FREE_GAP, FREE_GAP, FREE_GAP
        center = F(s[lo][0] - xi)
        lv = FREE_GAP
        llen = FREE_GAP
        for k in range(lo, len(s)):
            if F(s[k][0] - xi) > center:
                break
            j = s[k][1]
            lv = min(lv, self.v[j])
            llen = min(llen, self.plen[j])
        return center, lv, llen

    def scan_behind(self, target, xi):
        """(lag center, mask-min v) among dx < -EPS; FREE if none."""
        s = self._group(target)
        lo, hi = 0, len(s)
        while lo < hi:  # first index with x - xi >= -EPS
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) < -EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == 0:
            return FREE_GAP, FREE_GAP
        dx_last = F(s[lo - 1][0] - xi)
        lag_center = F(-dx_last)
        lag_v = FREE_GAP
        for k in range(lo - 1, -1, -1):
            if F(s[k][0] - xi) != dx_last:
                break
            lag_v = min(lag_v, self.v[s[k][1]])
        return lag_center, lag_v

    def leader(self, lane, i):
        xi = self.x[i]
        center, lv, llen = self.scan_ahead(lane[i], xi)
        if center >= FREE_GAP * F(0.5):
            return FREE_GAP, self.v[i], False
        return F(center - llen), lv, True

    def lane_gaps(self, i, target):
        xi = self.x[i]
        lead_center, lead_v, lead_len = self.scan_ahead(target, xi)
        lag_center, lag_v = self.scan_behind(target, xi)
        lead_has = lead_center < FREE_GAP * F(0.5)
        lag_has = lag_center < FREE_GAP * F(0.5)
        return (
            F(lead_center - lead_len) if lead_has else FREE_GAP,
            lead_v if lead_has else self.v[i],
            F(lag_center - self.plen[i]) if lag_has else FREE_GAP,
            lag_v if lag_has else self.v[i],
        )


# ------------------------------------------------------------------ driver
def random_traffic(rng, n, fill, n_lanes=3, tie_frac=0.15):
    x = np.zeros(n, dtype=F)
    v = rng.uniform(0.0, 32.0, n).astype(F)
    lane = rng.integers(0, n_lanes, n).astype(F)
    act = rng.uniform(0.0, 1.0, n) < fill
    plen = rng.uniform(4.0, 9.0, n).astype(F)
    pos = F(0.0)
    for i in range(n):
        pos = F(pos + F(rng.uniform(0.5, 40.0)))
        x[i] = pos
    # exact co-located ties (the mask-min tie-break case): copy x (and
    # sometimes lane) from a random earlier vehicle
    for i in range(1, n):
        if rng.uniform() < tie_frac:
            j = int(rng.integers(0, i))
            x[i] = x[j]
            if rng.uniform() < 0.5:
                lane[i] = lane[j]
    return x, v, lane, act, plen


def check(seed, n, fill):
    rng = np.random.default_rng(seed)
    x, v, lane, act, plen = random_traffic(rng, n, fill)
    idx = LaneIndex(x, v, lane, act, plen)
    lanes = sorted({int(round(float(l))) for l in lane} | {1})
    for i in range(n):
        if not act[i]:
            continue
        ref = leader_scan_ref(x, v, lane, act, plen, i)
        got = idx.leader(lane, i)
        assert ref == got, f"leader mismatch seed={seed} i={i}: {ref} vs {got}"
        for target in lanes:
            t = F(target)
            ref_g = lane_gap_scan_ref(x, v, lane, act, plen, i, t)
            got_g = idx.lane_gaps(i, t)
            assert ref_g == got_g, (
                f"lane_gaps mismatch seed={seed} i={i} target={target}: "
                f"{ref_g} vs {got_g}"
            )


def bench(n, fill, reps):
    rng = np.random.default_rng(12345)
    x, v, lane, act, plen = random_traffic(rng, n, fill, tie_frac=0.0)
    t0 = time.perf_counter()
    for _ in range(reps):
        for i in range(n):
            if act[i]:
                leader_scan_ref(x, v, lane, act, plen, i)
    t_ref = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        idx = LaneIndex(x, v, lane, act, plen)
        for i in range(n):
            if act[i]:
                idx.leader(lane, i)
    t_sweep = (time.perf_counter() - t0) / reps
    print(
        f"  N={n:4d} fill={fill}: reference {t_ref * 1e3:8.2f} ms/step-scan, "
        f"sweep {t_sweep * 1e3:8.2f} ms/step-scan  ->  {t_ref / t_sweep:5.1f}x"
    )
    return t_ref / t_sweep


def main():
    cases = 0
    for n in (64, 256):
        for fill in (0.2, 0.7, 1.0):
            for seed in range(12):
                check(seed * 7919 + n, n, fill)
                cases += 1
    print(f"bit-exactness: OK ({cases} randomized cases, N in {{64,256}}, "
          "ties + multi-lane)")
    print("algorithmic speedup of the leader pass (python mirror, "
          "indicative only):")
    bench(64, 0.7, 30)
    bench(256, 0.7, 8)


if __name__ == "__main__":
    main()
