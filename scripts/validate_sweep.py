#!/usr/bin/env python3
"""Pre-flight oracle for the rust native-stepper hot path.

PR 1 section — sorted-sweep neighbor index.  Mirrors, in numpy float32,
both neighbor-scan algorithms used by the native stepper:

  * the O(N^2) reference scans (``leader_scan`` / ``lane_gap_scan`` in
    ``rust/src/sumo/{idm,mobil}.rs``, themselves line-for-line ports of
    ``python/compile/kernels/ref.py``), and
  * the O(N log N) sorted-sweep versions (``rust/src/sumo/sweep.rs``):
    sort active slots by x once per lane per step, then find neighbors
    by partition point and resolve mask-min ties over the contiguous
    equal-dx run.

and asserts they are *bit-exact* (same gap, same mask-min tie-broken
speed/length selection, same exists flags) across randomized traffic:
varying fill, exact co-located ties, multiple lanes, N in {64, 256}.

PR 3 section — geometry-operand kernel.  Mirrors the FULL sim step
(IDM + phantom wall + MOBIL + integration) as a scalar float32 port of
``rust/src/sumo/{idm,mobil}.rs`` parameterized by the geometry vector,
and (when jax is importable) rolls it against the *actual*
geometry-operand kernel ``compile.model.step_geom`` on four family-like
geometries at their axis extremes — the pre-flight for
``rust/tests/scenario_families.rs::all_families_native_vs_hlo_track_at_extremes``.
It also times the scalar mirror vs the jitted kernel (solo and with a
mixed-geometry vmapped batch) on a non-default geometry.

PR 4 section — schema-3 destination dynamics.  The mirror (and the
params rows) carry the ``[exit_pos, exit_flag]`` columns: exit-flagged
vehicles see no phantom wall, bias mandatorily toward lane 1, and
retire crossing their own exit_pos on lane <= 1.  Every family geometry
is re-rolled with ~50% exit-flagged traffic against the jax kernel —
the pre-flight for
``rust/tests/scenario_families.rs::ramp_weave_off_traffic_actually_exits``.

PR 5 section — fused K-step rollouts.  ``model.rollout_geom`` (one
``lax.scan``-fused executable per ladder K) must be **bit-exact** with K
sequential ``step_geom`` dispatches — final state AND the whole
per-step obs trace, exits retiring mid-chunk inside the scan carry —
across every family geometry at its extremes.  This is the pre-flight
for ``rust/tests/runtime_numerics.rs::
rollout_bit_exact_with_sequential_all_families``.

Both timing sections estimate the speedups recorded in
``BENCH_runtime_hotpath.json`` (clearly labelled as python-mirror
estimates there; re-measure with ``cargo bench --bench runtime_hotpath``
on a machine with the rust toolchain).  ``--append-bench`` appends the
PR 5 rollout-mirror measurements (one jitted dispatch per step at K=1
vs one fused dispatch per K steps — the paired ``hlo_rollout/K=*``
rust bench cases) to that file; ``--append-bench-pr4`` re-appends the
older PR 4 step-kernel measurements.

Run: ``python3 scripts/validate_sweep.py [--append-bench]``
"""

import argparse
import json
import pathlib
import time

import numpy as np

F = np.float32
FREE_GAP = F(1.0e6)
EPS = F(1e-6)


# ---------------------------------------------------------------- reference
def leader_scan_ref(x, v, lane, act, plen, i):
    """Port of rust `leader_scan` (O(N) per ego)."""
    xi = x[i]
    li = lane[i]
    center = FREE_GAP
    n = len(x)
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx < center:
            center = dx
    if center >= FREE_GAP * F(0.5):
        return FREE_GAP, v[i], False
    lv = FREE_GAP
    llen = FREE_GAP
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx <= center:
            lv = min(lv, v[j])
            llen = min(llen, plen[j])
    return F(center - llen), lv, True


def lane_gap_scan_ref(x, v, lane, act, plen, i, target):
    """Port of rust `lane_gap_scan` (O(N) per ego/target)."""
    xi = x[i]
    n = len(x)
    lead_center = FREE_GAP
    lag_center = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS:
            lead_center = min(lead_center, dx)
        elif dx < -EPS:
            lag_center = min(lag_center, F(-dx))
    lead_v = FREE_GAP
    lead_len = FREE_GAP
    lag_v = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS and dx <= lead_center:
            lead_v = min(lead_v, v[j])
            lead_len = min(lead_len, plen[j])
        elif dx < -EPS and F(-dx) <= lag_center:
            lag_v = min(lag_v, v[j])
    lead_has = lead_center < FREE_GAP * F(0.5)
    lag_has = lag_center < FREE_GAP * F(0.5)
    return (
        F(lead_center - lead_len) if lead_has else FREE_GAP,
        lead_v if lead_has else v[i],
        F(lag_center - plen[i]) if lag_has else FREE_GAP,
        lag_v if lag_has else v[i],
    )


# ------------------------------------------------------------- sorted sweep
class LaneIndex:
    """Port of rust `sweep::LaneIndex`."""

    def __init__(self, x, v, lane, act, plen):
        self.x, self.v, self.plen = x, v, plen
        self.groups = {}  # lane key -> list[(x, slot)] sorted by x
        for i in range(len(x)):
            if not act[i]:
                continue
            key = int(round(float(lane[i])))
            self.groups.setdefault(key, []).append((x[i], i))
        for g in self.groups.values():
            g.sort(key=lambda e: float(e[0]))

    def _group(self, target):
        return self.groups.get(int(round(float(target))), [])

    def scan_ahead(self, target, xi):
        """(center, mask-min v, mask-min len) among dx > EPS; FREE if none."""
        s = self._group(target)
        # partition point: first index with x - xi > EPS
        lo, hi = 0, len(s)
        while lo < hi:
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) <= EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == len(s):
            return FREE_GAP, FREE_GAP, FREE_GAP
        center = F(s[lo][0] - xi)
        lv = FREE_GAP
        llen = FREE_GAP
        for k in range(lo, len(s)):
            if F(s[k][0] - xi) > center:
                break
            j = s[k][1]
            lv = min(lv, self.v[j])
            llen = min(llen, self.plen[j])
        return center, lv, llen

    def scan_behind(self, target, xi):
        """(lag center, mask-min v) among dx < -EPS; FREE if none."""
        s = self._group(target)
        lo, hi = 0, len(s)
        while lo < hi:  # first index with x - xi >= -EPS
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) < -EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == 0:
            return FREE_GAP, FREE_GAP
        dx_last = F(s[lo - 1][0] - xi)
        lag_center = F(-dx_last)
        lag_v = FREE_GAP
        for k in range(lo - 1, -1, -1):
            if F(s[k][0] - xi) != dx_last:
                break
            lag_v = min(lag_v, self.v[s[k][1]])
        return lag_center, lag_v

    def leader(self, lane, i):
        xi = self.x[i]
        center, lv, llen = self.scan_ahead(lane[i], xi)
        if center >= FREE_GAP * F(0.5):
            return FREE_GAP, self.v[i], False
        return F(center - llen), lv, True

    def lane_gaps(self, i, target):
        xi = self.x[i]
        lead_center, lead_v, lead_len = self.scan_ahead(target, xi)
        lag_center, lag_v = self.scan_behind(target, xi)
        lead_has = lead_center < FREE_GAP * F(0.5)
        lag_has = lag_center < FREE_GAP * F(0.5)
        return (
            F(lead_center - lead_len) if lead_has else FREE_GAP,
            lead_v if lead_has else self.v[i],
            F(lag_center - self.plen[i]) if lag_has else FREE_GAP,
            lag_v if lag_has else self.v[i],
        )


# ------------------------------------------------------------------ driver
def random_traffic(rng, n, fill, n_lanes=3, tie_frac=0.15):
    x = np.zeros(n, dtype=F)
    v = rng.uniform(0.0, 32.0, n).astype(F)
    lane = rng.integers(0, n_lanes, n).astype(F)
    act = rng.uniform(0.0, 1.0, n) < fill
    plen = rng.uniform(4.0, 9.0, n).astype(F)
    pos = F(0.0)
    for i in range(n):
        pos = F(pos + F(rng.uniform(0.5, 40.0)))
        x[i] = pos
    # exact co-located ties (the mask-min tie-break case): copy x (and
    # sometimes lane) from a random earlier vehicle
    for i in range(1, n):
        if rng.uniform() < tie_frac:
            j = int(rng.integers(0, i))
            x[i] = x[j]
            if rng.uniform() < 0.5:
                lane[i] = lane[j]
    return x, v, lane, act, plen


def check(seed, n, fill):
    rng = np.random.default_rng(seed)
    x, v, lane, act, plen = random_traffic(rng, n, fill)
    idx = LaneIndex(x, v, lane, act, plen)
    lanes = sorted({int(round(float(l))) for l in lane} | {1})
    for i in range(n):
        if not act[i]:
            continue
        ref = leader_scan_ref(x, v, lane, act, plen, i)
        got = idx.leader(lane, i)
        assert ref == got, f"leader mismatch seed={seed} i={i}: {ref} vs {got}"
        for target in lanes:
            t = F(target)
            ref_g = lane_gap_scan_ref(x, v, lane, act, plen, i, t)
            got_g = idx.lane_gaps(i, t)
            assert ref_g == got_g, (
                f"lane_gaps mismatch seed={seed} i={i} target={target}: "
                f"{ref_g} vs {got_g}"
            )


def bench(n, fill, reps):
    rng = np.random.default_rng(12345)
    x, v, lane, act, plen = random_traffic(rng, n, fill, tie_frac=0.0)
    t0 = time.perf_counter()
    for _ in range(reps):
        for i in range(n):
            if act[i]:
                leader_scan_ref(x, v, lane, act, plen, i)
    t_ref = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        idx = LaneIndex(x, v, lane, act, plen)
        for i in range(n):
            if act[i]:
                idx.leader(lane, i)
    t_sweep = (time.perf_counter() - t0) / reps
    print(
        f"  N={n:4d} fill={fill}: reference {t_ref * 1e3:8.2f} ms/step-scan, "
        f"sweep {t_sweep * 1e3:8.2f} ms/step-scan  ->  {t_ref / t_sweep:5.1f}x"
    )
    return t_ref / t_sweep


# =====================================================================
# PR 3: the geometry-operand step — scalar float32 mirror of the native
# stepper (rust/src/sumo/{idm,mobil}.rs) under a runtime geometry
# =====================================================================

MIN_GAP = F(0.5)
SAFE_DECEL = F(4.0)
THRESHOLD = F(0.2)
POLITENESS = F(0.3)
RAMP_LANE = F(0.0)

#: family-like geometries at their axis extremes, as
#: (road_end, merge_start, merge_end, num_main_lanes, dt) — the same
#: corners rust/tests/scenario_families.rs drives (family.rs spaces).
FAMILY_GEOMETRIES = {
    "highway-merge-lo": (1000.0, 300.0, 450.0, 1, 0.1),
    "highway-merge-hi": (1000.0, 300.0, 600.0, 3, 0.1),
    "lane-drop-lo": (700.0, 300.0, 400.0, 1, 0.1),
    "lane-drop-hi": (1000.0, 450.0, 700.0, 3, 0.1),
    "ramp-weave-lo": (1000.0, 300.0, 450.0, 2, 0.1),
    "ramp-weave-hi": (1000.0, 300.0, 650.0, 3, 0.1),
    "ring-shockwave-lo": (1200.0, 0.0, 0.0, 1, 0.1),
    "ring-shockwave-hi": (3600.0, 0.0, 0.0, 2, 0.1),
}


def idm_law(v, gap, dv, has, p):
    """Port of rust ``idm_law`` (p = one params row, float32)."""
    s = max(gap, MIN_GAP)
    v0 = max(p[0], F(0.1))
    a_max = max(p[2], F(1e-3))
    b = max(p[3], F(1e-3))
    s_star = max(F(p[4] + v * p[1] + v * dv / F(2.0 * np.sqrt(F(a_max * b)))), F(0.0))
    free = F(1.0 - F(v / v0) ** 4)
    inter = F(s_star / s) ** 2 if has else F(0.0)
    return F(a_max * F(free - inter))


def wall_accel(x, v, lane, p, merge_end):
    """Port of rust ``wall_accel`` under an operand merge_end.  Exit-
    flagged vehicles (p[7] > 0.5) see no wall — their road continues
    through the off-ramp gore."""
    if abs(F(lane - RAMP_LANE)) < F(0.5) and p[7] <= F(0.5):
        gap = max(F(merge_end - x), F(MIN_GAP * F(0.1)))
    else:
        gap = FREE_GAP
    return idm_law(v, gap, v, gap < FREE_GAP * F(0.5), p)


def step_native_mirror(x, v, lane, act, params, geometry):
    """One full step of the native stepper mirror (scalar float32) under
    ``geometry``; mutates the arrays in place like the rust stepper."""
    road_end, merge_start, merge_end, n_lanes, dt = geometry
    road_end, merge_start, merge_end = F(road_end), F(merge_start), F(merge_end)
    max_lane = F(float(n_lanes))
    dt = F(dt)
    n = len(x)
    plen = params[:, 5]

    accel = np.zeros(n, dtype=F)
    for i in range(n):
        if not act[i]:
            continue
        gap, lv, has = leader_scan_ref(x, v, lane, act, plen, i)
        p = tuple(params[i])
        a = idm_law(v[i], gap, F(v[i] - lv), has, p)
        accel[i] = min(a, wall_accel(x[i], v[i], lane[i], p, merge_end))

    def incentive(i, target):
        lead_gap, lead_v, lag_gap, lag_v = lane_gap_scan_ref(
            x, v, lane, act, plen, i, F(target)
        )
        p = tuple(params[i])
        a_self = idm_law(v[i], lead_gap, F(v[i] - lead_v), lead_gap < FREE_GAP * F(0.5), p)
        a_lag = idm_law(lag_v, lag_gap, F(lag_v - v[i]), lag_gap < FREE_GAP * F(0.5), p)
        s0 = params[i, 4]
        safe = lead_gap > s0 and lag_gap > s0 and a_lag > -SAFE_DECEL
        return a_self, a_lag, safe

    decisions = [None] * n
    for i in range(n):
        if not act[i]:
            continue
        if abs(F(lane[i] - RAMP_LANE)) < F(0.5):
            if merge_start <= x[i] <= merge_end and incentive(i, 1.0)[2]:
                decisions[i] = F(1.0)
            continue
        tgt_dn = max(F(lane[i] - F(1.0)), F(1.0))
        if params[i, 7] > F(0.5):
            # mandatory exit-intent bias: toward lane 1 whenever safe,
            # never a discretionary move away from the exit
            if tgt_dn < lane[i] - F(0.5) and incentive(i, tgt_dn)[2]:
                decisions[i] = tgt_dn
            continue
        tgt_up = min(F(lane[i] + F(1.0)), max_lane)
        if tgt_up > lane[i] + F(0.5):
            a_self, a_lag, safe = incentive(i, tgt_up)
            gain = F(a_self - accel[i] - POLITENESS * max(F(-a_lag), F(0.0)))
            if safe and gain > THRESHOLD:
                decisions[i] = tgt_up
                continue
        if tgt_dn < lane[i] - F(0.5):
            a_self, a_lag, safe = incentive(i, tgt_dn)
            gain = F(a_self - accel[i] - POLITENESS * max(F(-a_lag), F(0.0)))
            if safe and gain > THRESHOLD:
                decisions[i] = tgt_dn

    n_exited = 0
    for i in range(n):
        if not act[i]:
            v[i] = F(0.0)
            continue
        if decisions[i] is not None:
            lane[i] = decisions[i]
        new_v = max(F(v[i] + accel[i] * dt), F(0.0))
        new_x = F(x[i] + new_v * dt)
        crossed = new_x >= road_end and x[i] < road_end
        exited = (
            not crossed
            and params[i, 7] > F(0.5)
            and lane[i] < F(1.5)
            and new_x >= params[i, 6]
            and x[i] < params[i, 6]
        )
        if crossed or exited:
            act[i] = False
        if exited:
            n_exited += 1
        x[i], v[i] = new_x, new_v
    return n_exited


def geometry_traffic(rng, n, geometry, with_ramp, exit_frac=0.0, near_gore=False):
    """Random traffic scaled to the geometry's road (float32).  With
    ``exit_frac`` > 0, that share of vehicles carries schema-3 exit
    intent (exit at the merge-zone gore, or mid-road when the geometry
    has no zone); ``near_gore`` clusters the spawn span just upstream of
    the gore so short rollouts actually produce exit crossings."""
    road_end, _, merge_end, n_lanes, _ = geometry
    gore = merge_end if merge_end > 0.0 else road_end * 0.6
    if near_gore:
        x = np.sort(rng.uniform(max(0.0, gore - 400.0), gore * 1.02, n)).astype(F)
    else:
        x = np.sort(rng.uniform(0.0, road_end * 0.9, n)).astype(F)
    x += np.arange(n, dtype=F) * F(0.01)  # keep the dx > eps test stable
    v = rng.uniform(0.0, 30.0, n).astype(F)
    lo_lane = 0 if with_ramp else 1
    lane = rng.integers(lo_lane, n_lanes + 1, n).astype(F)
    act = rng.uniform(0.0, 1.0, n) < 0.7
    flagged = rng.uniform(0.0, 1.0, n) < exit_frac
    params = np.stack(
        [
            rng.uniform(20.0, 38.0, n),
            rng.uniform(0.9, 2.2, n),
            rng.uniform(1.0, 2.5, n),
            rng.uniform(1.5, 3.5, n),
            rng.uniform(1.5, 3.0, n),
            rng.uniform(4.0, 9.0, n),
            np.where(flagged, gore, 0.0),
            flagged.astype(F),
        ],
        axis=1,
    ).astype(F)
    return x, v, lane, act, params


def check_geometry_kernel(
    jnp, model, name, geometry, seed, steps=20, exit_frac=0.0, near_gore=False
):
    """Roll the jax geometry-operand kernel against the scalar mirror —
    the tolerance discipline of rust/tests/runtime_numerics.rs (both
    sides integrate the same f32 math in different op orders).  Returns
    the mirror's total exit count over the rollout."""
    rng = np.random.default_rng(seed)
    n = 64
    with_ramp = geometry[2] > 0.0  # families with a merge zone use lane 0
    x, v, lane, act, params = geometry_traffic(
        rng, n, geometry, with_ramp, exit_frac, near_gore
    )
    geom_row = jnp.asarray(np.array(geometry, dtype=F))
    state_j = jnp.stack(
        [
            jnp.asarray(x.copy()),
            jnp.asarray(v.copy()),
            jnp.asarray(lane.copy()),
            jnp.asarray(act.astype(F)),
        ],
        axis=1,
    )
    params_j = jnp.asarray(params)
    # exit-flagged rollouts retire on a lane-change boundary too, so they
    # get one extra step of allowed retirement skew; the exit-free
    # baseline keeps the original strict bound
    mismatch_tol = 2 if exit_frac > 0.0 else 1
    exits = 0
    for step in range(steps):
        state_j, _, _, _ = model.step_geom(state_j, params_j, geom_row)
        exits += step_native_mirror(x, v, lane, act, params, geometry)
        sj = np.asarray(state_j)
        active_mismatch = int(np.sum((sj[:, 3] > 0.5) != act))
        assert active_mismatch <= mismatch_tol, (
            f"{name} step {step}: {active_mismatch} active-flag mismatches"
        )
        both = (sj[:, 3] > 0.5) & act
        dx = np.abs(sj[both, 0] - x[both])
        dv = np.abs(sj[both, 1] - v[both])
        assert dx.size == 0 or dx.max() < 0.5, f"{name} step {step}: max |dx| {dx.max()}"
        assert dv.size == 0 or dv.max() < 0.5, f"{name} step {step}: max |dv| {dv.max()}"
    return exits


def bench_geometry_kernel(jnp, jax, model):
    """Time the scalar native mirror vs the jitted geometry-operand
    kernel on the lane-drop-hi geometry, plus a mixed-geometry vmapped
    batch — the python-mirror estimates for BENCH_runtime_hotpath.json.
    Returns {bench_name: (ns_per_iter, iters, steps_per_s)}."""
    results = {}
    geometry = FAMILY_GEOMETRIES["lane-drop-hi"]
    step_jit = jax.jit(model.step_geom)
    for n, reps in ((64, 30), (256, 8)):
        rng = np.random.default_rng(99)
        # a quarter of the traffic is exit-flagged so the schema-3
        # destination branch is part of what both sides pay for
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        t0 = time.perf_counter()
        for _ in range(reps):
            xx, vv, ll, aa = x.copy(), v.copy(), lane.copy(), act.copy()
            step_native_mirror(xx, vv, ll, aa, params, geometry)
        t_native = (time.perf_counter() - t0) / reps

        state = jnp.stack(
            [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
            axis=1,
        )
        pj = jnp.asarray(params)
        g = jnp.asarray(np.array(geometry, dtype=F))
        step_jit(state, pj, g)[0].block_until_ready()  # compile once (pooled)
        jit_reps = reps * 20
        t0 = time.perf_counter()
        for _ in range(jit_reps):
            step_jit(state, pj, g)[0].block_until_ready()
        t_hlo = (time.perf_counter() - t0) / jit_reps
        results[f"mirror_native_step_geom/lane-drop/N={n}"] = (t_native, reps)
        results[f"mirror_hlo_step_geom/lane-drop/N={n}"] = (t_hlo, jit_reps)
        print(
            f"  N={n:4d} lane-drop-hi: native mirror {t_native * 1e3:8.2f} ms/step, "
            f"geometry-operand kernel {t_hlo * 1e3:8.3f} ms/step  ->  "
            f"{t_native / t_hlo:6.1f}x"
        )

    # mixed-family batched dispatch: 8 lanes, 4 distinct geometry rows
    b, n = 8, 64
    stepb_jit = jax.jit(jax.vmap(model.step_geom))
    picks = ["highway-merge-hi", "lane-drop-hi", "ramp-weave-hi", "ring-shockwave-hi"]
    rng = np.random.default_rng(7)
    states, geoms = [], []
    params_all = []
    for k in range(b):
        geometry = FAMILY_GEOMETRIES[picks[k % len(picks)]]
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        states.append(np.stack([x, v, lane, act.astype(F)], axis=1))
        params_all.append(params)
        geoms.append(np.array(geometry, dtype=F))
    bs = jnp.asarray(np.stack(states))
    bp = jnp.asarray(np.stack(params_all))
    bg = jnp.asarray(np.stack(geoms))
    stepb_jit(bs, bp, bg)[0].block_until_ready()
    reps = 200
    t0 = time.perf_counter()
    for _ in range(reps):
        stepb_jit(bs, bp, bg)[0].block_until_ready()
    t_batched = (time.perf_counter() - t0) / reps
    results[f"mirror_hlo_step_geom_batched_mixed/B={b}/N={n}"] = (t_batched / b, reps)
    print(
        f"  B={b} N={n} mixed-family batch: {t_batched * 1e3:8.3f} ms/dispatch "
        f"({t_batched / b * 1e3:.3f} ms amortized per instance)"
    )
    return results


# =====================================================================
# PR 5: fused K-step rollouts — bit-exactness oracle + dispatch-
# amortization mirror for the `hlo_rollout/K=*` rust bench cases
# =====================================================================

#: the lowered K ladder (aot.py ROLLOUT_STEPS; pinned by
#: scripts/check_manifest.py).
ROLLOUT_STEPS = (1, 8, 32)


def check_rollout_bit_exact(jax, jnp, model, name, geometry, seed, k=32, exit_frac=0.35):
    """Fused ``rollout_geom`` vs K sequential ``step_geom`` calls — both
    jit-compiled (the lowered executables are the ABI, not the eager
    path) and required to agree BIT-exactly: final state and the whole
    per-step obs trace.  Exit-flagged traffic spawns near the gore so
    retirements land mid-chunk, inside the scan carry.  Returns the
    rollout's total exit count."""
    rng = np.random.default_rng(seed)
    n = 64
    with_ramp = geometry[2] > 0.0
    x, v, lane, act, params = geometry_traffic(
        rng, n, geometry, with_ramp, exit_frac, near_gore=True
    )
    state = jnp.stack(
        [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
        axis=1,
    )
    pj = jnp.asarray(params)
    geom_row = jnp.asarray(np.array(geometry, dtype=F))
    step_jit = jax.jit(model.step_geom)
    roll_jit = jax.jit(model.rollout_geom, static_argnums=3)

    seq_state = state
    seq_obs = []
    for _ in range(k):
        seq_state, _, _, obs = step_jit(seq_state, pj, geom_row)
        seq_obs.append(np.asarray(obs))
    seq_obs = np.stack(seq_obs)
    fin, trace = roll_jit(state, pj, geom_row, k)
    assert np.array_equal(np.asarray(fin), np.asarray(seq_state)), (
        f"{name}: fused K={k} final state != {k} sequential steps"
    )
    assert np.array_equal(np.asarray(trace), seq_obs), (
        f"{name}: fused K={k} obs trace != sequential"
    )
    return int(seq_obs[:, 4].sum())


def bench_rollout_kernel(jax, jnp, model):
    """Time the fused rollout at each ladder K on the lane-drop-hi
    geometry — the python-mirror stand-in for the rust
    `hlo_rollout/K={1,8,32}/N=*` bench cases.  K=1 is one jitted
    dispatch per physics step (the pre-PR5 hot path, dispatch overhead
    included); K=8/32 amortize that overhead over the fused chunk.
    Returns {bench_name: (sec_per_dispatch, iters, steps_per_s)}."""
    results = {}
    geometry = FAMILY_GEOMETRIES["lane-drop-hi"]
    for n in (16, 64, 256):
        rng = np.random.default_rng(123)
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        state = jnp.stack(
            [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
            axis=1,
        )
        pj = jnp.asarray(params)
        g = jnp.asarray(np.array(geometry, dtype=F))
        line = [f"  N={n:4d}:"]
        per_k = {}
        for k in ROLLOUT_STEPS:
            fn = jax.jit(lambda s, p, gg, kk=k: model.rollout_geom(s, p, gg, kk))
            fn(state, pj, g)[0].block_until_ready()
            reps = max(8, 400 // k)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(state, pj, g)[0].block_until_ready()
            sec = (time.perf_counter() - t0) / reps
            sps = k / sec
            per_k[k] = sps
            results[f"mirror_hlo_rollout/K={k}/N={n}"] = (sec, reps, sps)
            line.append(f"K={k} {sps:8.0f} steps/s")
        k_lo, k_hi = ROLLOUT_STEPS[0], ROLLOUT_STEPS[-1]
        line.append(f"-> K={k_hi} {per_k[k_hi] / per_k[k_lo]:5.2f}x over K={k_lo}")
        print(" ".join(line))
    return results


def append_bench_pr5(results):
    """Append the PR 5 rollout-mirror runs to BENCH_runtime_hotpath.json
    (never deleting existing runs): pre = one dispatch per step (K=1),
    post = fused K-step dispatches."""
    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    pre = {k: v for k, v in results.items() if "/K=1/" in k}
    post = {k: v for k, v in results.items() if "/K=1/" not in k}
    for label, rows in (
        (
            "pre-PR5-python-mirror (jax schema-4 kernel, ONE jitted dispatch per "
            "physics step — the per-step host round-trip the fused rollouts "
            "remove; 25% exit-flagged, lane-drop geometry, float32)",
            pre,
        ),
        (
            "post-PR5-python-mirror (jax fused lax.scan rollout executables, one "
            "dispatch per K-step chunk, same traffic — bit-exact with the "
            "sequential path, dispatch overhead amortized K-fold)",
            post,
        ),
    ):
        doc["runs"].append(
            {
                "label": label,
                "unix_time": int(time.time()),
                "source": "scripts/validate_sweep.py",
                "results": [
                    {
                        "name": name,
                        "ns_per_iter": int(sec * 1e9),
                        "iters": iters,
                        "steps_per_s": round(sps, 1),
                    }
                    for name, (sec, iters, sps) in sorted(rows.items())
                ],
            }
        )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended pre/post-PR5 python-mirror runs to {path}")


def rollout_section(do_append):
    try:
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "python"))
        import jax
        import jax.numpy as jnp

        from compile import model
    except ImportError as e:
        print(f"rollout section skipped (no jax here: {e})")
        return
    total_exits = 0
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        total_exits += check_rollout_bit_exact(
            jax, jnp, model, name, geometry, seed=7000 + i
        )
    # the windows are one K=32 chunk each (vs the PR 4 section's 60-step
    # rollouts), so a handful of mid-chunk exits across the extremes is
    # the expected yield — zero would mean the destination dynamics never
    # exercised the scan carry
    assert total_exits >= 3, f"rollout sweeps produced too few exits: {total_exits}"
    print(
        f"fused-rollout bit-exactness: OK ({len(FAMILY_GEOMETRIES)} family extremes, "
        f"K=32 fused vs 32 sequential jitted steps, {total_exits} exits mid-chunk)"
    )
    print("fused-rollout dispatch amortization (python mirror, indicative only):")
    results = bench_rollout_kernel(jax, jnp, model)
    if do_append:
        append_bench_pr5(results)


def append_bench(results):
    """Append the PR 4 python-mirror measurements to
    BENCH_runtime_hotpath.json (never deleting existing runs)."""
    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    pre = {k: v for k, v in results.items() if k.startswith("mirror_native")}
    post = {k: v for k, v in results.items() if not k.startswith("mirror_native")}
    for label, rows in (
        (
            "pre-PR4-python-mirror (scalar native full step, schema-3 "
            "destination-aware, 25% exit-flagged, lane-drop geometry, float32)",
            pre,
        ),
        (
            "post-PR4-python-mirror (jax schema-3 destination-aware step_geom "
            "kernel, CPU jit stand-in for the pooled PJRT executable; solo + "
            "mixed-family batched, 25% exit-flagged)",
            post,
        ),
    ):
        doc["runs"].append(
            {
                "label": label,
                "unix_time": int(time.time()),
                "source": "scripts/validate_sweep.py",
                "results": [
                    {
                        "name": name,
                        "ns_per_iter": int(sec * 1e9),
                        "iters": iters,
                        "steps_per_s": round(1.0 / sec, 1),
                    }
                    for name, (sec, iters) in sorted(rows.items())
                ],
            }
        )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended pre/post-PR4 python-mirror runs to {path}")


def geometry_section(do_append):
    try:
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "python"))
        import jax
        import jax.numpy as jnp

        from compile import model
    except ImportError as e:
        print(f"geometry-operand section skipped (no jax here: {e})")
        return
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        check_geometry_kernel(jnp, model, name, geometry, seed=1000 + i)
    print(
        f"geometry-operand agreement: OK ({len(FAMILY_GEOMETRIES)} family extremes, "
        "20-step rollouts, jax kernel vs scalar native mirror)"
    )
    # PR 4: the same extremes with ~30% exit-flagged traffic — the
    # destination columns must agree too, and exits must actually occur
    total_exits = 0
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        total_exits += check_geometry_kernel(
            jnp, model, name, geometry, seed=4000 + i, steps=60, exit_frac=0.5,
            near_gore=True,
        )
    assert total_exits >= 10, f"exit-flagged sweeps produced too few exits: {total_exits}"
    print(
        f"destination-dynamics agreement: OK (same extremes, 50% exit-flagged, "
        f"60-step rollouts, {total_exits} off-ramp exits mirrored)"
    )
    print("geometry-operand step timing (python mirror, indicative only):")
    results = bench_geometry_kernel(jnp, jax, model)
    if do_append:
        append_bench(results)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--append-bench",
        action="store_true",
        help="append the PR 5 rollout-mirror runs to BENCH_runtime_hotpath.json",
    )
    ap.add_argument(
        "--append-bench-pr4",
        action="store_true",
        help="re-append the PR 4 step-kernel measurements (older mode)",
    )
    args = ap.parse_args()

    cases = 0
    for n in (64, 256):
        for fill in (0.2, 0.7, 1.0):
            for seed in range(12):
                check(seed * 7919 + n, n, fill)
                cases += 1
    print(f"bit-exactness: OK ({cases} randomized cases, N in {{64,256}}, "
          "ties + multi-lane)")
    print("algorithmic speedup of the leader pass (python mirror, "
          "indicative only):")
    bench(64, 0.7, 30)
    bench(256, 0.7, 8)
    geometry_section(args.append_bench_pr4)
    rollout_section(args.append_bench)


if __name__ == "__main__":
    main()
