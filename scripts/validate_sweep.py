#!/usr/bin/env python3
"""Pre-flight oracle for the rust native-stepper hot path.

PR 1 section — sorted-sweep neighbor index.  Mirrors, in numpy float32,
both neighbor-scan algorithms used by the native stepper:

  * the O(N^2) reference scans (``leader_scan`` / ``lane_gap_scan`` in
    ``rust/src/sumo/{idm,mobil}.rs``, themselves line-for-line ports of
    ``python/compile/kernels/ref.py``), and
  * the O(N log N) sorted-sweep versions (``rust/src/sumo/sweep.rs``):
    sort active slots by x once per lane per step, then find neighbors
    by partition point and resolve mask-min ties over the contiguous
    equal-dx run.

and asserts they are *bit-exact* (same gap, same mask-min tie-broken
speed/length selection, same exists flags) across randomized traffic:
varying fill, exact co-located ties, multiple lanes, N in {64, 256}.

PR 3 section — geometry-operand kernel.  Mirrors the FULL sim step
(IDM + phantom wall + MOBIL + integration) as a scalar float32 port of
``rust/src/sumo/{idm,mobil}.rs`` parameterized by the geometry vector,
and (when jax is importable) rolls it against the *actual*
geometry-operand kernel ``compile.model.step_geom`` on four family-like
geometries at their axis extremes — the pre-flight for
``rust/tests/scenario_families.rs::all_families_native_vs_hlo_track_at_extremes``.
It also times the scalar mirror vs the jitted kernel (solo and with a
mixed-geometry vmapped batch) on a non-default geometry.

PR 4 section — schema-3 destination dynamics.  The mirror (and the
params rows) carry the ``[exit_pos, exit_flag]`` columns: exit-flagged
vehicles see no phantom wall, bias mandatorily toward lane 1, and
retire crossing their own exit_pos on lane <= 1.  Every family geometry
is re-rolled with ~50% exit-flagged traffic against the jax kernel —
the pre-flight for
``rust/tests/scenario_families.rs::ramp_weave_off_traffic_actually_exits``.

PR 5 section — fused K-step rollouts.  ``model.rollout_geom`` (one
``lax.scan``-fused executable per ladder K) must be **bit-exact** with K
sequential ``step_geom`` dispatches — final state AND the whole
per-step obs trace, exits retiring mid-chunk inside the scan carry —
across every family geometry at its extremes.  This is the pre-flight
for ``rust/tests/runtime_numerics.rs::
rollout_bit_exact_with_sequential_all_families``.

PR 10 section — device-resident whole runs.  ``model.run_geom``
compiles the departure schedule into the kernel as an operand table
``f32[D, DEP_COLS]``, so an entire run (insertion + physics + exits) is
ONE dispatch.  The oracle replays every family extreme two ways — the
fused ``run_geom`` executable vs sequential jitted ``step_geom`` steps
with a host-side insertion mirror between them (the pre-PR10 execution
model: due rows insert into the first inactive slot unless clearance-
blocked, blocked rows queue and retry) — and requires **bit**-equality
on the final state, the final params (insertions mutate them), the
whole obs trace, and the end-of-run insertion mask.  Forced co-located
same-epoch spawn pairs guarantee the clearance-blocked retry path is
exercised in-kernel.  This is the pre-flight for
``rust/tests/runtime_numerics.rs::
whole_run_resident_bit_exact_with_chunked_all_families``.

All timing sections estimate the speedups recorded in
``BENCH_runtime_hotpath.json`` (clearly labelled as python-mirror
estimates there; the container this grows in has NO rust toolchain, so
re-measure with ``cargo bench --bench runtime_hotpath`` on a machine
that does).  ``--append-bench`` appends the PR 5 rollout-mirror
measurements (one jitted dispatch per step at K=1 vs one fused dispatch
per K steps — the paired ``hlo_rollout/K=*`` rust bench cases) to that
file; ``--append-bench-pr4`` re-appends the older PR 4 step-kernel
measurements; ``--append-bench-pr10`` appends the PR 10 whole-run
measurements (PR-5 chunk scheduler breaking at every departure boundary
vs one ``run_geom`` dispatch — the paired ``hlo_run/T=*`` rust bench
cases), which must clear the >= 2x steps/s acceptance bar at N <= 64.

Run: ``python3 scripts/validate_sweep.py [--append-bench-pr10]``
"""

import argparse
import json
import pathlib
import time

import numpy as np

F = np.float32
FREE_GAP = F(1.0e6)
EPS = F(1e-6)


# ---------------------------------------------------------------- reference
def leader_scan_ref(x, v, lane, act, plen, i):
    """Port of rust `leader_scan` (O(N) per ego)."""
    xi = x[i]
    li = lane[i]
    center = FREE_GAP
    n = len(x)
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx < center:
            center = dx
    if center >= FREE_GAP * F(0.5):
        return FREE_GAP, v[i], False
    lv = FREE_GAP
    llen = FREE_GAP
    for j in range(n):
        if not act[j]:
            continue
        dx = F(x[j] - xi)
        if dx > EPS and abs(F(lane[j] - li)) < F(0.5) and dx <= center:
            lv = min(lv, v[j])
            llen = min(llen, plen[j])
    return F(center - llen), lv, True


def lane_gap_scan_ref(x, v, lane, act, plen, i, target):
    """Port of rust `lane_gap_scan` (O(N) per ego/target)."""
    xi = x[i]
    n = len(x)
    lead_center = FREE_GAP
    lag_center = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS:
            lead_center = min(lead_center, dx)
        elif dx < -EPS:
            lag_center = min(lag_center, F(-dx))
    lead_v = FREE_GAP
    lead_len = FREE_GAP
    lag_v = FREE_GAP
    for j in range(n):
        if not act[j] or abs(F(lane[j] - target)) >= F(0.5):
            continue
        dx = F(x[j] - xi)
        if dx > EPS and dx <= lead_center:
            lead_v = min(lead_v, v[j])
            lead_len = min(lead_len, plen[j])
        elif dx < -EPS and F(-dx) <= lag_center:
            lag_v = min(lag_v, v[j])
    lead_has = lead_center < FREE_GAP * F(0.5)
    lag_has = lag_center < FREE_GAP * F(0.5)
    return (
        F(lead_center - lead_len) if lead_has else FREE_GAP,
        lead_v if lead_has else v[i],
        F(lag_center - plen[i]) if lag_has else FREE_GAP,
        lag_v if lag_has else v[i],
    )


# ------------------------------------------------------------- sorted sweep
class LaneIndex:
    """Port of rust `sweep::LaneIndex`."""

    def __init__(self, x, v, lane, act, plen):
        self.x, self.v, self.plen = x, v, plen
        self.groups = {}  # lane key -> list[(x, slot)] sorted by x
        for i in range(len(x)):
            if not act[i]:
                continue
            key = int(round(float(lane[i])))
            self.groups.setdefault(key, []).append((x[i], i))
        for g in self.groups.values():
            g.sort(key=lambda e: float(e[0]))

    def _group(self, target):
        return self.groups.get(int(round(float(target))), [])

    def scan_ahead(self, target, xi):
        """(center, mask-min v, mask-min len) among dx > EPS; FREE if none."""
        s = self._group(target)
        # partition point: first index with x - xi > EPS
        lo, hi = 0, len(s)
        while lo < hi:
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) <= EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == len(s):
            return FREE_GAP, FREE_GAP, FREE_GAP
        center = F(s[lo][0] - xi)
        lv = FREE_GAP
        llen = FREE_GAP
        for k in range(lo, len(s)):
            if F(s[k][0] - xi) > center:
                break
            j = s[k][1]
            lv = min(lv, self.v[j])
            llen = min(llen, self.plen[j])
        return center, lv, llen

    def scan_behind(self, target, xi):
        """(lag center, mask-min v) among dx < -EPS; FREE if none."""
        s = self._group(target)
        lo, hi = 0, len(s)
        while lo < hi:  # first index with x - xi >= -EPS
            mid = (lo + hi) // 2
            if F(s[mid][0] - xi) < -EPS:
                lo = mid + 1
            else:
                hi = mid
        if lo == 0:
            return FREE_GAP, FREE_GAP
        dx_last = F(s[lo - 1][0] - xi)
        lag_center = F(-dx_last)
        lag_v = FREE_GAP
        for k in range(lo - 1, -1, -1):
            if F(s[k][0] - xi) != dx_last:
                break
            lag_v = min(lag_v, self.v[s[k][1]])
        return lag_center, lag_v

    def leader(self, lane, i):
        xi = self.x[i]
        center, lv, llen = self.scan_ahead(lane[i], xi)
        if center >= FREE_GAP * F(0.5):
            return FREE_GAP, self.v[i], False
        return F(center - llen), lv, True

    def lane_gaps(self, i, target):
        xi = self.x[i]
        lead_center, lead_v, lead_len = self.scan_ahead(target, xi)
        lag_center, lag_v = self.scan_behind(target, xi)
        lead_has = lead_center < FREE_GAP * F(0.5)
        lag_has = lag_center < FREE_GAP * F(0.5)
        return (
            F(lead_center - lead_len) if lead_has else FREE_GAP,
            lead_v if lead_has else self.v[i],
            F(lag_center - self.plen[i]) if lag_has else FREE_GAP,
            lag_v if lag_has else self.v[i],
        )


# ------------------------------------------------------------------ driver
def random_traffic(rng, n, fill, n_lanes=3, tie_frac=0.15):
    x = np.zeros(n, dtype=F)
    v = rng.uniform(0.0, 32.0, n).astype(F)
    lane = rng.integers(0, n_lanes, n).astype(F)
    act = rng.uniform(0.0, 1.0, n) < fill
    plen = rng.uniform(4.0, 9.0, n).astype(F)
    pos = F(0.0)
    for i in range(n):
        pos = F(pos + F(rng.uniform(0.5, 40.0)))
        x[i] = pos
    # exact co-located ties (the mask-min tie-break case): copy x (and
    # sometimes lane) from a random earlier vehicle
    for i in range(1, n):
        if rng.uniform() < tie_frac:
            j = int(rng.integers(0, i))
            x[i] = x[j]
            if rng.uniform() < 0.5:
                lane[i] = lane[j]
    return x, v, lane, act, plen


def check(seed, n, fill):
    rng = np.random.default_rng(seed)
    x, v, lane, act, plen = random_traffic(rng, n, fill)
    idx = LaneIndex(x, v, lane, act, plen)
    lanes = sorted({int(round(float(l))) for l in lane} | {1})
    for i in range(n):
        if not act[i]:
            continue
        ref = leader_scan_ref(x, v, lane, act, plen, i)
        got = idx.leader(lane, i)
        assert ref == got, f"leader mismatch seed={seed} i={i}: {ref} vs {got}"
        for target in lanes:
            t = F(target)
            ref_g = lane_gap_scan_ref(x, v, lane, act, plen, i, t)
            got_g = idx.lane_gaps(i, t)
            assert ref_g == got_g, (
                f"lane_gaps mismatch seed={seed} i={i} target={target}: "
                f"{ref_g} vs {got_g}"
            )


def bench(n, fill, reps):
    rng = np.random.default_rng(12345)
    x, v, lane, act, plen = random_traffic(rng, n, fill, tie_frac=0.0)
    t0 = time.perf_counter()
    for _ in range(reps):
        for i in range(n):
            if act[i]:
                leader_scan_ref(x, v, lane, act, plen, i)
    t_ref = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    for _ in range(reps):
        idx = LaneIndex(x, v, lane, act, plen)
        for i in range(n):
            if act[i]:
                idx.leader(lane, i)
    t_sweep = (time.perf_counter() - t0) / reps
    print(
        f"  N={n:4d} fill={fill}: reference {t_ref * 1e3:8.2f} ms/step-scan, "
        f"sweep {t_sweep * 1e3:8.2f} ms/step-scan  ->  {t_ref / t_sweep:5.1f}x"
    )
    return t_ref / t_sweep


# =====================================================================
# PR 3: the geometry-operand step — scalar float32 mirror of the native
# stepper (rust/src/sumo/{idm,mobil}.rs) under a runtime geometry
# =====================================================================

MIN_GAP = F(0.5)
SAFE_DECEL = F(4.0)
THRESHOLD = F(0.2)
POLITENESS = F(0.3)
RAMP_LANE = F(0.0)

#: family-like geometries at their axis extremes, as
#: (road_end, merge_start, merge_end, num_main_lanes, dt) — the same
#: corners rust/tests/scenario_families.rs drives (family.rs spaces).
FAMILY_GEOMETRIES = {
    "highway-merge-lo": (1000.0, 300.0, 450.0, 1, 0.1),
    "highway-merge-hi": (1000.0, 300.0, 600.0, 3, 0.1),
    "lane-drop-lo": (700.0, 300.0, 400.0, 1, 0.1),
    "lane-drop-hi": (1000.0, 450.0, 700.0, 3, 0.1),
    "ramp-weave-lo": (1000.0, 300.0, 450.0, 2, 0.1),
    "ramp-weave-hi": (1000.0, 300.0, 650.0, 3, 0.1),
    "ring-shockwave-lo": (1200.0, 0.0, 0.0, 1, 0.1),
    "ring-shockwave-hi": (3600.0, 0.0, 0.0, 2, 0.1),
}


def idm_law(v, gap, dv, has, p):
    """Port of rust ``idm_law`` (p = one params row, float32)."""
    s = max(gap, MIN_GAP)
    v0 = max(p[0], F(0.1))
    a_max = max(p[2], F(1e-3))
    b = max(p[3], F(1e-3))
    s_star = max(F(p[4] + v * p[1] + v * dv / F(2.0 * np.sqrt(F(a_max * b)))), F(0.0))
    free = F(1.0 - F(v / v0) ** 4)
    inter = F(s_star / s) ** 2 if has else F(0.0)
    return F(a_max * F(free - inter))


def wall_accel(x, v, lane, p, merge_end):
    """Port of rust ``wall_accel`` under an operand merge_end.  Exit-
    flagged vehicles (p[7] > 0.5) see no wall — their road continues
    through the off-ramp gore."""
    if abs(F(lane - RAMP_LANE)) < F(0.5) and p[7] <= F(0.5):
        gap = max(F(merge_end - x), F(MIN_GAP * F(0.1)))
    else:
        gap = FREE_GAP
    return idm_law(v, gap, v, gap < FREE_GAP * F(0.5), p)


def step_native_mirror(x, v, lane, act, params, geometry):
    """One full step of the native stepper mirror (scalar float32) under
    ``geometry``; mutates the arrays in place like the rust stepper."""
    road_end, merge_start, merge_end, n_lanes, dt = geometry
    road_end, merge_start, merge_end = F(road_end), F(merge_start), F(merge_end)
    max_lane = F(float(n_lanes))
    dt = F(dt)
    n = len(x)
    plen = params[:, 5]

    accel = np.zeros(n, dtype=F)
    for i in range(n):
        if not act[i]:
            continue
        gap, lv, has = leader_scan_ref(x, v, lane, act, plen, i)
        p = tuple(params[i])
        a = idm_law(v[i], gap, F(v[i] - lv), has, p)
        accel[i] = min(a, wall_accel(x[i], v[i], lane[i], p, merge_end))

    def incentive(i, target):
        lead_gap, lead_v, lag_gap, lag_v = lane_gap_scan_ref(
            x, v, lane, act, plen, i, F(target)
        )
        p = tuple(params[i])
        a_self = idm_law(v[i], lead_gap, F(v[i] - lead_v), lead_gap < FREE_GAP * F(0.5), p)
        a_lag = idm_law(lag_v, lag_gap, F(lag_v - v[i]), lag_gap < FREE_GAP * F(0.5), p)
        s0 = params[i, 4]
        safe = lead_gap > s0 and lag_gap > s0 and a_lag > -SAFE_DECEL
        return a_self, a_lag, safe

    decisions = [None] * n
    for i in range(n):
        if not act[i]:
            continue
        if abs(F(lane[i] - RAMP_LANE)) < F(0.5):
            if merge_start <= x[i] <= merge_end and incentive(i, 1.0)[2]:
                decisions[i] = F(1.0)
            continue
        tgt_dn = max(F(lane[i] - F(1.0)), F(1.0))
        if params[i, 7] > F(0.5):
            # mandatory exit-intent bias: toward lane 1 whenever safe,
            # never a discretionary move away from the exit
            if tgt_dn < lane[i] - F(0.5) and incentive(i, tgt_dn)[2]:
                decisions[i] = tgt_dn
            continue
        tgt_up = min(F(lane[i] + F(1.0)), max_lane)
        if tgt_up > lane[i] + F(0.5):
            a_self, a_lag, safe = incentive(i, tgt_up)
            gain = F(a_self - accel[i] - POLITENESS * max(F(-a_lag), F(0.0)))
            if safe and gain > THRESHOLD:
                decisions[i] = tgt_up
                continue
        if tgt_dn < lane[i] - F(0.5):
            a_self, a_lag, safe = incentive(i, tgt_dn)
            gain = F(a_self - accel[i] - POLITENESS * max(F(-a_lag), F(0.0)))
            if safe and gain > THRESHOLD:
                decisions[i] = tgt_dn

    n_exited = 0
    for i in range(n):
        if not act[i]:
            v[i] = F(0.0)
            continue
        if decisions[i] is not None:
            lane[i] = decisions[i]
        new_v = max(F(v[i] + accel[i] * dt), F(0.0))
        new_x = F(x[i] + new_v * dt)
        crossed = new_x >= road_end and x[i] < road_end
        exited = (
            not crossed
            and params[i, 7] > F(0.5)
            and lane[i] < F(1.5)
            and new_x >= params[i, 6]
            and x[i] < params[i, 6]
        )
        if crossed or exited:
            act[i] = False
        if exited:
            n_exited += 1
        x[i], v[i] = new_x, new_v
    return n_exited


def geometry_traffic(rng, n, geometry, with_ramp, exit_frac=0.0, near_gore=False):
    """Random traffic scaled to the geometry's road (float32).  With
    ``exit_frac`` > 0, that share of vehicles carries schema-3 exit
    intent (exit at the merge-zone gore, or mid-road when the geometry
    has no zone); ``near_gore`` clusters the spawn span just upstream of
    the gore so short rollouts actually produce exit crossings."""
    road_end, _, merge_end, n_lanes, _ = geometry
    gore = merge_end if merge_end > 0.0 else road_end * 0.6
    if near_gore:
        x = np.sort(rng.uniform(max(0.0, gore - 400.0), gore * 1.02, n)).astype(F)
    else:
        x = np.sort(rng.uniform(0.0, road_end * 0.9, n)).astype(F)
    x += np.arange(n, dtype=F) * F(0.01)  # keep the dx > eps test stable
    v = rng.uniform(0.0, 30.0, n).astype(F)
    lo_lane = 0 if with_ramp else 1
    lane = rng.integers(lo_lane, n_lanes + 1, n).astype(F)
    act = rng.uniform(0.0, 1.0, n) < 0.7
    flagged = rng.uniform(0.0, 1.0, n) < exit_frac
    params = np.stack(
        [
            rng.uniform(20.0, 38.0, n),
            rng.uniform(0.9, 2.2, n),
            rng.uniform(1.0, 2.5, n),
            rng.uniform(1.5, 3.5, n),
            rng.uniform(1.5, 3.0, n),
            rng.uniform(4.0, 9.0, n),
            np.where(flagged, gore, 0.0),
            flagged.astype(F),
        ],
        axis=1,
    ).astype(F)
    return x, v, lane, act, params


def check_geometry_kernel(
    jnp, model, name, geometry, seed, steps=20, exit_frac=0.0, near_gore=False
):
    """Roll the jax geometry-operand kernel against the scalar mirror —
    the tolerance discipline of rust/tests/runtime_numerics.rs (both
    sides integrate the same f32 math in different op orders).  Returns
    the mirror's total exit count over the rollout."""
    rng = np.random.default_rng(seed)
    n = 64
    with_ramp = geometry[2] > 0.0  # families with a merge zone use lane 0
    x, v, lane, act, params = geometry_traffic(
        rng, n, geometry, with_ramp, exit_frac, near_gore
    )
    geom_row = jnp.asarray(np.array(geometry, dtype=F))
    state_j = jnp.stack(
        [
            jnp.asarray(x.copy()),
            jnp.asarray(v.copy()),
            jnp.asarray(lane.copy()),
            jnp.asarray(act.astype(F)),
        ],
        axis=1,
    )
    params_j = jnp.asarray(params)
    # exit-flagged rollouts retire on a lane-change boundary too, so they
    # get one extra step of allowed retirement skew; the exit-free
    # baseline keeps the original strict bound
    mismatch_tol = 2 if exit_frac > 0.0 else 1
    exits = 0
    for step in range(steps):
        state_j, _, _, _ = model.step_geom(state_j, params_j, geom_row)
        exits += step_native_mirror(x, v, lane, act, params, geometry)
        sj = np.asarray(state_j)
        active_mismatch = int(np.sum((sj[:, 3] > 0.5) != act))
        assert active_mismatch <= mismatch_tol, (
            f"{name} step {step}: {active_mismatch} active-flag mismatches"
        )
        both = (sj[:, 3] > 0.5) & act
        dx = np.abs(sj[both, 0] - x[both])
        dv = np.abs(sj[both, 1] - v[both])
        assert dx.size == 0 or dx.max() < 0.5, f"{name} step {step}: max |dx| {dx.max()}"
        assert dv.size == 0 or dv.max() < 0.5, f"{name} step {step}: max |dv| {dv.max()}"
    return exits


def bench_geometry_kernel(jnp, jax, model):
    """Time the scalar native mirror vs the jitted geometry-operand
    kernel on the lane-drop-hi geometry, plus a mixed-geometry vmapped
    batch — the python-mirror estimates for BENCH_runtime_hotpath.json.
    Returns {bench_name: (ns_per_iter, iters, steps_per_s)}."""
    results = {}
    geometry = FAMILY_GEOMETRIES["lane-drop-hi"]
    step_jit = jax.jit(model.step_geom)
    for n, reps in ((64, 30), (256, 8)):
        rng = np.random.default_rng(99)
        # a quarter of the traffic is exit-flagged so the schema-3
        # destination branch is part of what both sides pay for
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        t0 = time.perf_counter()
        for _ in range(reps):
            xx, vv, ll, aa = x.copy(), v.copy(), lane.copy(), act.copy()
            step_native_mirror(xx, vv, ll, aa, params, geometry)
        t_native = (time.perf_counter() - t0) / reps

        state = jnp.stack(
            [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
            axis=1,
        )
        pj = jnp.asarray(params)
        g = jnp.asarray(np.array(geometry, dtype=F))
        step_jit(state, pj, g)[0].block_until_ready()  # compile once (pooled)
        jit_reps = reps * 20
        t0 = time.perf_counter()
        for _ in range(jit_reps):
            step_jit(state, pj, g)[0].block_until_ready()
        t_hlo = (time.perf_counter() - t0) / jit_reps
        results[f"mirror_native_step_geom/lane-drop/N={n}"] = (t_native, reps)
        results[f"mirror_hlo_step_geom/lane-drop/N={n}"] = (t_hlo, jit_reps)
        print(
            f"  N={n:4d} lane-drop-hi: native mirror {t_native * 1e3:8.2f} ms/step, "
            f"geometry-operand kernel {t_hlo * 1e3:8.3f} ms/step  ->  "
            f"{t_native / t_hlo:6.1f}x"
        )

    # mixed-family batched dispatch: 8 lanes, 4 distinct geometry rows
    b, n = 8, 64
    stepb_jit = jax.jit(jax.vmap(model.step_geom))
    picks = ["highway-merge-hi", "lane-drop-hi", "ramp-weave-hi", "ring-shockwave-hi"]
    rng = np.random.default_rng(7)
    states, geoms = [], []
    params_all = []
    for k in range(b):
        geometry = FAMILY_GEOMETRIES[picks[k % len(picks)]]
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        states.append(np.stack([x, v, lane, act.astype(F)], axis=1))
        params_all.append(params)
        geoms.append(np.array(geometry, dtype=F))
    bs = jnp.asarray(np.stack(states))
    bp = jnp.asarray(np.stack(params_all))
    bg = jnp.asarray(np.stack(geoms))
    stepb_jit(bs, bp, bg)[0].block_until_ready()
    reps = 200
    t0 = time.perf_counter()
    for _ in range(reps):
        stepb_jit(bs, bp, bg)[0].block_until_ready()
    t_batched = (time.perf_counter() - t0) / reps
    results[f"mirror_hlo_step_geom_batched_mixed/B={b}/N={n}"] = (t_batched / b, reps)
    print(
        f"  B={b} N={n} mixed-family batch: {t_batched * 1e3:8.3f} ms/dispatch "
        f"({t_batched / b * 1e3:.3f} ms amortized per instance)"
    )
    return results


# =====================================================================
# PR 5: fused K-step rollouts — bit-exactness oracle + dispatch-
# amortization mirror for the `hlo_rollout/K=*` rust bench cases
# =====================================================================

#: the lowered K ladder (aot.py ROLLOUT_STEPS; pinned by
#: scripts/check_manifest.py).
ROLLOUT_STEPS = (1, 8, 32)


def check_rollout_bit_exact(jax, jnp, model, name, geometry, seed, k=32, exit_frac=0.35):
    """Fused ``rollout_geom`` vs K sequential ``step_geom`` calls — both
    jit-compiled (the lowered executables are the ABI, not the eager
    path) and required to agree BIT-exactly: final state and the whole
    per-step obs trace.  Exit-flagged traffic spawns near the gore so
    retirements land mid-chunk, inside the scan carry.  Returns the
    rollout's total exit count."""
    rng = np.random.default_rng(seed)
    n = 64
    with_ramp = geometry[2] > 0.0
    x, v, lane, act, params = geometry_traffic(
        rng, n, geometry, with_ramp, exit_frac, near_gore=True
    )
    state = jnp.stack(
        [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
        axis=1,
    )
    pj = jnp.asarray(params)
    geom_row = jnp.asarray(np.array(geometry, dtype=F))
    step_jit = jax.jit(model.step_geom)
    roll_jit = jax.jit(model.rollout_geom, static_argnums=3)

    seq_state = state
    seq_obs = []
    for _ in range(k):
        seq_state, _, _, obs = step_jit(seq_state, pj, geom_row)
        seq_obs.append(np.asarray(obs))
    seq_obs = np.stack(seq_obs)
    fin, trace = roll_jit(state, pj, geom_row, k)
    assert np.array_equal(np.asarray(fin), np.asarray(seq_state)), (
        f"{name}: fused K={k} final state != {k} sequential steps"
    )
    assert np.array_equal(np.asarray(trace), seq_obs), (
        f"{name}: fused K={k} obs trace != sequential"
    )
    return int(seq_obs[:, 4].sum())


def bench_rollout_kernel(jax, jnp, model):
    """Time the fused rollout at each ladder K on the lane-drop-hi
    geometry — the python-mirror stand-in for the rust
    `hlo_rollout/K={1,8,32}/N=*` bench cases.  K=1 is one jitted
    dispatch per physics step (the pre-PR5 hot path, dispatch overhead
    included); K=8/32 amortize that overhead over the fused chunk.
    Returns {bench_name: (sec_per_dispatch, iters, steps_per_s)}."""
    results = {}
    geometry = FAMILY_GEOMETRIES["lane-drop-hi"]
    for n in (16, 64, 256):
        rng = np.random.default_rng(123)
        x, v, lane, act, params = geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
        state = jnp.stack(
            [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(F))],
            axis=1,
        )
        pj = jnp.asarray(params)
        g = jnp.asarray(np.array(geometry, dtype=F))
        line = [f"  N={n:4d}:"]
        per_k = {}
        for k in ROLLOUT_STEPS:
            fn = jax.jit(lambda s, p, gg, kk=k: model.rollout_geom(s, p, gg, kk))
            fn(state, pj, g)[0].block_until_ready()
            reps = max(8, 400 // k)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn(state, pj, g)[0].block_until_ready()
            sec = (time.perf_counter() - t0) / reps
            sps = k / sec
            per_k[k] = sps
            results[f"mirror_hlo_rollout/K={k}/N={n}"] = (sec, reps, sps)
            line.append(f"K={k} {sps:8.0f} steps/s")
        k_lo, k_hi = ROLLOUT_STEPS[0], ROLLOUT_STEPS[-1]
        line.append(f"-> K={k_hi} {per_k[k_hi] / per_k[k_lo]:5.2f}x over K={k_lo}")
        print(" ".join(line))
    return results


def append_bench_pr5(results):
    """Append the PR 5 rollout-mirror runs to BENCH_runtime_hotpath.json
    (never deleting existing runs): pre = one dispatch per step (K=1),
    post = fused K-step dispatches."""
    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    pre = {k: v for k, v in results.items() if "/K=1/" in k}
    post = {k: v for k, v in results.items() if "/K=1/" not in k}
    for label, rows in (
        (
            "pre-PR5-python-mirror (jax schema-4 kernel, ONE jitted dispatch per "
            "physics step — the per-step host round-trip the fused rollouts "
            "remove; 25% exit-flagged, lane-drop geometry, float32)",
            pre,
        ),
        (
            "post-PR5-python-mirror (jax fused lax.scan rollout executables, one "
            "dispatch per K-step chunk, same traffic — bit-exact with the "
            "sequential path, dispatch overhead amortized K-fold)",
            post,
        ),
    ):
        doc["runs"].append(
            {
                "label": label,
                "unix_time": int(time.time()),
                "source": "scripts/validate_sweep.py",
                "results": [
                    {
                        "name": name,
                        "ns_per_iter": int(sec * 1e9),
                        "iters": iters,
                        "steps_per_s": round(sps, 1),
                    }
                    for name, (sec, iters, sps) in sorted(rows.items())
                ],
            }
        )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended pre/post-PR5 python-mirror runs to {path}")


def rollout_section(do_append):
    try:
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "python"))
        import jax
        import jax.numpy as jnp

        from compile import model
    except ImportError as e:
        print(f"rollout section skipped (no jax here: {e})")
        return
    total_exits = 0
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        total_exits += check_rollout_bit_exact(
            jax, jnp, model, name, geometry, seed=7000 + i
        )
    # the windows are one K=32 chunk each (vs the PR 4 section's 60-step
    # rollouts), so a handful of mid-chunk exits across the extremes is
    # the expected yield — zero would mean the destination dynamics never
    # exercised the scan carry
    assert total_exits >= 3, f"rollout sweeps produced too few exits: {total_exits}"
    print(
        f"fused-rollout bit-exactness: OK ({len(FAMILY_GEOMETRIES)} family extremes, "
        f"K=32 fused vs 32 sequential jitted steps, {total_exits} exits mid-chunk)"
    )
    print("fused-rollout dispatch amortization (python mirror, indicative only):")
    results = bench_rollout_kernel(jax, jnp, model)
    if do_append:
        append_bench_pr5(results)


# =====================================================================
# PR 10: device-resident whole runs — departure insertion compiled into
# the kernel.  Bit-exactness oracle + dispatch-amortization mirror for
# the `hlo_run/T=*` rust bench cases
# =====================================================================

#: the lowered whole-run ladder and table height (aot.py RUN_STEPS /
#: DEPARTURE_ROWS; pinned by scripts/check_manifest.py).
RUN_LADDER = (200, 1200, 1800)
DEPARTURE_ROWS = 256
DEP_COLS = 12  # ["step", "x", "v", "lane"] + the 8 params columns
DEP_PAD_EPOCH = F(2.0**30)


def host_insert_mirror(state, params, table, inserted, cursor, step_idx,
                       insert_step=None):
    """One step of the HOST-side departure scheduler — the numpy mirror
    of both the rust sequential scheduler and ``run_geom``'s in-kernel
    insertion phase.  Scans rows ``[cursor, hi)`` in ascending order
    (``hi`` = count of due rows; epochs ascend), inserts each unblocked
    pending row into the FIRST inactive slot, leaves clearance-blocked
    rows pending (the insertion queue), and returns the new cursor (the
    first uninserted row).  Mutates state/params/inserted in place."""
    step_f = F(step_idx)
    d = table.shape[0]
    hi = int(np.sum(table[:, 0] <= step_f))
    for j in range(cursor, hi):
        row = table[j]
        if row[0] > step_f or inserted[j] >= 0.5:
            continue
        occupied = state[:, 3] > 0.5
        same_lane = np.abs(state[:, 2] - row[3]) < 0.5
        clearance = F(row[8] + row[9])  # s0 + length
        near = np.abs(state[:, 0] - row[1]) < clearance
        if bool(np.any(occupied & same_lane & near)):
            continue  # blocked: stays pending, retries next step
        slot = int(np.argmin(state[:, 3]))
        if state[slot, 3] >= 0.5:
            continue  # no free slot
        state[slot] = (row[1], row[2], row[3], F(1.0))
        params[slot] = row[4:]
        inserted[j] = F(1.0)
        if insert_step is not None:
            insert_step[j] = step_idx
    open_rows = np.flatnonzero((np.arange(d) >= cursor) & (inserted < 0.5))
    return int(open_rows[0]) if open_rows.size else d


def make_run_case(rng, geometry, t_total, n=64, d_rows=64, n_spawns=24):
    """Initial traffic (thinned so slots are free for insertions) plus a
    sorted schema-5 departure table: ``n_spawns`` upstream spawns spread
    over the first 80% of the run, padding rows at ``DEP_PAD_EPOCH``.
    Two spawn pairs share an epoch, a lane and (nearly) a position, so
    the second of each pair is clearance-blocked by the first insertion
    and must retry from the queue on later steps."""
    road_end, _, merge_end, n_lanes, _ = geometry
    with_ramp = merge_end > 0.0
    x, v, lane, act, params = geometry_traffic(
        rng, n, geometry, with_ramp, exit_frac=0.4, near_gore=True
    )
    act &= rng.uniform(0.0, 1.0, n) < 0.6
    gore = merge_end if merge_end > 0.0 else road_end * 0.6
    table = np.zeros((d_rows, DEP_COLS), dtype=F)
    table[:, 0] = DEP_PAD_EPOCH
    epochs = np.sort(rng.integers(0, max(int(t_total * 0.8), 1), n_spawns))
    for i, epoch in enumerate(epochs):
        flagged = rng.uniform() < 0.25
        table[i] = [
            F(epoch), F(rng.uniform(0.0, 30.0)), F(rng.uniform(8.0, 20.0)),
            F(float(rng.integers(1, int(n_lanes) + 1))),
            F(rng.uniform(20.0, 38.0)), F(rng.uniform(0.9, 2.2)),
            F(rng.uniform(1.0, 2.5)), F(rng.uniform(1.5, 3.5)),
            F(rng.uniform(1.5, 3.0)), F(rng.uniform(4.0, 9.0)),
            F(gore) if flagged else F(0.0), F(1.0) if flagged else F(0.0),
        ]
    for i in (4, 12):
        if i + 1 < n_spawns:
            table[i + 1, 0] = table[i, 0]
            table[i + 1, 3] = table[i, 3]
            table[i + 1, 1] = F(table[i, 1] + F(1.0))
    return x, v, lane, act, params, table


def check_run_bit_exact(jax, jnp, model, name, geometry, seed, t_total=200):
    """Fused ``run_geom`` (one dispatch, demand as an operand) vs the
    pre-PR10 execution model (host insertion mirror between ``t_total``
    sequential jitted ``step_geom`` dispatches), required to agree
    BIT-exactly: final state, final params, obs trace, insertion mask.
    Returns (insertions, queue-delayed insertions, exits)."""
    rng = np.random.default_rng(seed)
    x, v, lane, act, params, table = make_run_case(rng, geometry, t_total)
    state = np.stack([x, v, lane, act.astype(F)], axis=1)
    g = jnp.asarray(np.array(geometry, dtype=F))
    run_jit = jax.jit(model.run_geom, static_argnums=4)
    step_jit = jax.jit(model.step_geom)

    fin_s, fin_p, trace, inserted = run_jit(
        jnp.asarray(state), jnp.asarray(params), g, jnp.asarray(table), t_total
    )

    s_np, p_np = state.copy(), params.copy()
    ins_np = np.zeros(table.shape[0], dtype=F)
    insert_step = np.full(table.shape[0], -1, dtype=np.int64)
    cursor = 0
    seq_obs = []
    for step in range(t_total):
        cursor = host_insert_mirror(
            s_np, p_np, table, ins_np, cursor, step, insert_step
        )
        out = step_jit(jnp.asarray(s_np), jnp.asarray(p_np), g)
        s_np = np.array(out[0])  # writable copy: insertion mutates it
        seq_obs.append(np.asarray(out[3]))
    seq_obs = np.stack(seq_obs)

    assert np.array_equal(np.asarray(fin_s), s_np), (
        f"{name}: fused whole run final state != sequential+host insertion"
    )
    assert np.array_equal(np.asarray(fin_p), p_np), (
        f"{name}: final params diverged (insertion payloads)"
    )
    assert np.array_equal(np.asarray(trace), seq_obs), (
        f"{name}: whole-run obs trace != sequential"
    )
    assert np.array_equal(np.asarray(inserted), ins_np), (
        f"{name}: insertion mask diverged"
    )
    done = ins_np > 0.5
    queued = int(np.sum(insert_step[done] > table[done, 0]))
    return int(ins_np.sum()), queued, int(seq_obs[:, 4].sum())


def bench_run_kernel(jax, jnp, model):
    """Time a whole run both ways on the lane-drop-hi geometry: the
    PR-5 chunk scheduler mirror (fused ladder chunks, but the host must
    break at every departure boundary — and single-step while a blocked
    row is queued — to run its insertion phase) vs ONE ``run_geom``
    dispatch.  Demand is constant-rate (a spawn every ~7 steps, the
    regime the 256-row table is sized for), so chunking stays dispatch-
    bound exactly as the rust `hlo_run/T=*` vs `hlo_rollout/K=32` bench
    pairing does.  Asserts the acceptance bar: the whole-run path must
    clear >= 2x steps/s at every N <= 64 rung.
    Returns {bench_name: (sec_per_run, iters, steps_per_s)}."""
    results = {}
    geometry = FAMILY_GEOMETRIES["lane-drop-hi"]
    g = jnp.asarray(np.array(geometry, dtype=F))
    roll_fns = {
        k: jax.jit(lambda s, p, gg, kk=k: model.rollout_geom(s, p, gg, kk))
        for k in ROLLOUT_STEPS
    }
    run_jit = jax.jit(model.run_geom, static_argnums=4)
    for n in (16, 64):
        for t_total in RUN_LADDER:
            rng = np.random.default_rng(31337 + n + t_total)
            n_spawns = min(DEPARTURE_ROWS - 32, max(16, t_total // 7))
            x, v, lane, act, params, table = make_run_case(
                rng, geometry, t_total, n=n, d_rows=DEPARTURE_ROWS,
                n_spawns=n_spawns,
            )
            state = np.stack([x, v, lane, act.astype(F)], axis=1)
            epochs = table[:, 0]

            def chunked_once():
                s_np, p_np = state.copy(), params.copy()
                ins = np.zeros(table.shape[0], dtype=F)
                cursor, step_idx, dispatches = 0, 0, 0
                while step_idx < t_total:
                    cursor = host_insert_mirror(
                        s_np, p_np, table, ins, cursor, step_idx
                    )
                    if np.any((epochs <= F(step_idx)) & (ins < 0.5)):
                        boundary = step_idx + 1  # queued row retries next step
                    else:
                        future = epochs[(ins < 0.5) & (epochs < DEP_PAD_EPOCH * F(0.5))]
                        boundary = int(future.min()) if future.size else t_total
                    boundary = min(max(boundary, step_idx + 1), t_total)
                    rem = boundary - step_idx
                    k = 32 if rem >= 32 else (8 if rem >= 8 else 1)
                    out, _ = roll_fns[k](jnp.asarray(s_np), jnp.asarray(p_np), g)
                    s_np = np.array(out)  # writable copy: insertion mutates it
                    dispatches += 1
                    step_idx += k
                return dispatches

            dispatches = chunked_once()  # warm the ladder compiles
            reps = 3 if t_total > 400 else 6
            t0 = time.perf_counter()
            for _ in range(reps):
                chunked_once()
            t_pre = (time.perf_counter() - t0) / reps

            sj, pj, tj = jnp.asarray(state), jnp.asarray(params), jnp.asarray(table)
            run_jit(sj, pj, g, tj, t_total)[0].block_until_ready()
            post_reps = reps * 4
            t0 = time.perf_counter()
            for _ in range(post_reps):
                run_jit(sj, pj, g, tj, t_total)[0].block_until_ready()
            t_post = (time.perf_counter() - t0) / post_reps

            pre_sps, post_sps = t_total / t_pre, t_total / t_post
            results[f"mirror_chunked_run/T={t_total}/N={n}"] = (t_pre, reps, pre_sps)
            results[f"mirror_hlo_run/T={t_total}/N={n}"] = (t_post, post_reps, post_sps)
            print(
                f"  N={n:4d} T={t_total:4d}: chunked {dispatches:3d} dispatches "
                f"{pre_sps:8.0f} steps/s, whole-run 1 dispatch "
                f"{post_sps:8.0f} steps/s  ->  {post_sps / pre_sps:5.2f}x"
            )
            assert post_sps >= 2.0 * pre_sps, (
                f"whole-run acceptance failed at N={n} T={t_total}: "
                f"{post_sps:.0f} vs {pre_sps:.0f} steps/s (< 2x)"
            )
    return results


def append_bench_pr10(results):
    """Append the PR 10 whole-run mirror runs to
    BENCH_runtime_hotpath.json (never deleting existing runs): pre = the
    PR-5 chunk scheduler breaking at every departure boundary, post =
    one ``run_geom`` dispatch per run."""
    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    pre = {k: v for k, v in results.items() if k.startswith("mirror_chunked_run")}
    post = {k: v for k, v in results.items() if k.startswith("mirror_hlo_run")}
    for label, rows in (
        (
            "pre-PR10-python-mirror (PR-5 chunk scheduler: fused ladder chunks "
            "broken at every departure boundary for host-side insertion, "
            "constant-rate demand, lane-drop geometry — NO rust toolchain in "
            "this container, re-measure with `cargo bench --bench "
            "runtime_hotpath`)",
            pre,
        ),
        (
            "post-PR10-python-mirror (whole run as ONE run_geom dispatch, "
            "departure table compiled in as an operand; bit-exact with the "
            "chunked path, >= 2x steps/s at N <= 64 asserted by "
            "scripts/validate_sweep.py)",
            post,
        ),
    ):
        doc["runs"].append(
            {
                "label": label,
                "unix_time": int(time.time()),
                "source": "scripts/validate_sweep.py",
                "results": [
                    {
                        "name": name,
                        "ns_per_iter": int(sec * 1e9),
                        "iters": iters,
                        "steps_per_s": round(sps, 1),
                    }
                    for name, (sec, iters, sps) in sorted(rows.items())
                ],
            }
        )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended pre/post-PR10 python-mirror runs to {path}")


def run_section(do_append):
    try:
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "python"))
        import jax
        import jax.numpy as jnp

        from compile import model
    except ImportError as e:
        print(f"whole-run section skipped (no jax here: {e})")
        return
    total_ins, total_queued, total_exits = 0, 0, 0
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        ins, queued, exits = check_run_bit_exact(
            jax, jnp, model, name, geometry, seed=9000 + i
        )
        total_ins += ins
        total_queued += queued
        total_exits += exits
    # every extreme schedules 24 spawns; most must land, several must be
    # clearance-blocked first (the forced pairs), and the exit dynamics
    # must fire inside the fused window — otherwise the oracle never
    # exercised the in-kernel queue or the scan-carry retirement
    assert total_ins >= 80, f"whole-run sweeps inserted too few: {total_ins}"
    assert total_queued >= 4, (
        f"no clearance-blocked retries exercised in-kernel: {total_queued}"
    )
    assert total_exits >= 8, f"whole-run sweeps produced too few exits: {total_exits}"
    print(
        f"whole-run bit-exactness: OK ({len(FAMILY_GEOMETRIES)} family extremes, "
        f"T=200 fused vs 200 sequential jitted steps + host insertion; "
        f"{total_ins} insertions, {total_queued} queue-delayed, "
        f"{total_exits} exits in-kernel)"
    )
    print("whole-run dispatch amortization (python mirror, indicative only):")
    results = bench_run_kernel(jax, jnp, model)
    if do_append:
        append_bench_pr10(results)


def append_bench(results):
    """Append the PR 4 python-mirror measurements to
    BENCH_runtime_hotpath.json (never deleting existing runs)."""
    path = pathlib.Path(__file__).resolve().parents[1] / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    pre = {k: v for k, v in results.items() if k.startswith("mirror_native")}
    post = {k: v for k, v in results.items() if not k.startswith("mirror_native")}
    for label, rows in (
        (
            "pre-PR4-python-mirror (scalar native full step, schema-3 "
            "destination-aware, 25% exit-flagged, lane-drop geometry, float32)",
            pre,
        ),
        (
            "post-PR4-python-mirror (jax schema-3 destination-aware step_geom "
            "kernel, CPU jit stand-in for the pooled PJRT executable; solo + "
            "mixed-family batched, 25% exit-flagged)",
            post,
        ),
    ):
        doc["runs"].append(
            {
                "label": label,
                "unix_time": int(time.time()),
                "source": "scripts/validate_sweep.py",
                "results": [
                    {
                        "name": name,
                        "ns_per_iter": int(sec * 1e9),
                        "iters": iters,
                        "steps_per_s": round(1.0 / sec, 1),
                    }
                    for name, (sec, iters) in sorted(rows.items())
                ],
            }
        )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended pre/post-PR4 python-mirror runs to {path}")


def geometry_section(do_append):
    try:
        import sys

        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "python"))
        import jax
        import jax.numpy as jnp

        from compile import model
    except ImportError as e:
        print(f"geometry-operand section skipped (no jax here: {e})")
        return
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        check_geometry_kernel(jnp, model, name, geometry, seed=1000 + i)
    print(
        f"geometry-operand agreement: OK ({len(FAMILY_GEOMETRIES)} family extremes, "
        "20-step rollouts, jax kernel vs scalar native mirror)"
    )
    # PR 4: the same extremes with ~30% exit-flagged traffic — the
    # destination columns must agree too, and exits must actually occur
    total_exits = 0
    for i, (name, geometry) in enumerate(FAMILY_GEOMETRIES.items()):
        total_exits += check_geometry_kernel(
            jnp, model, name, geometry, seed=4000 + i, steps=60, exit_frac=0.5,
            near_gore=True,
        )
    assert total_exits >= 10, f"exit-flagged sweeps produced too few exits: {total_exits}"
    print(
        f"destination-dynamics agreement: OK (same extremes, 50% exit-flagged, "
        f"60-step rollouts, {total_exits} off-ramp exits mirrored)"
    )
    print("geometry-operand step timing (python mirror, indicative only):")
    results = bench_geometry_kernel(jnp, jax, model)
    if do_append:
        append_bench(results)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--append-bench",
        action="store_true",
        help="append the PR 5 rollout-mirror runs to BENCH_runtime_hotpath.json",
    )
    ap.add_argument(
        "--append-bench-pr4",
        action="store_true",
        help="re-append the PR 4 step-kernel measurements (older mode)",
    )
    ap.add_argument(
        "--append-bench-pr10",
        action="store_true",
        help="append the PR 10 whole-run mirror runs to BENCH_runtime_hotpath.json",
    )
    args = ap.parse_args()

    cases = 0
    for n in (64, 256):
        for fill in (0.2, 0.7, 1.0):
            for seed in range(12):
                check(seed * 7919 + n, n, fill)
                cases += 1
    print(f"bit-exactness: OK ({cases} randomized cases, N in {{64,256}}, "
          "ties + multi-lane)")
    print("algorithmic speedup of the leader pass (python mirror, "
          "indicative only):")
    bench(64, 0.7, 30)
    bench(256, 0.7, 8)
    geometry_section(args.append_bench_pr4)
    rollout_section(args.append_bench)
    run_section(args.append_bench_pr10)


if __name__ == "__main__":
    main()
