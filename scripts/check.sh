#!/usr/bin/env bash
# Pre-PR gate (EXPERIMENTS.md, ROADMAP.md): formatting, lints, and the
# tier-1 build/test command.  Run from anywhere inside the repo.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo build --examples =="
cargo build --examples

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "check.sh: all gates passed"
