#!/usr/bin/env bash
# Pre-PR gate (EXPERIMENTS.md, ROADMAP.md): formatting, lints, and the
# tier-1 build/test command.  Run from anywhere inside the repo.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== xtask lint (AST-accurate project rules) =="
# rust/xtask replaces the old grep deny-attr gate and the awk print
# gate: panic-freedom (unwrap/expect/indexing + lint.allow), lock
# discipline in fabric/coordinator.rs, print-freedom with real
# #[cfg(test)] extents, ledger-before-event ordering, and deny-attr
# presence — all at token level, not line-regex level.
if cargo run -q -p xtask -- lint 2>/dev/null; then
  :
else
  # xtask is its own workspace root; fall back to an explicit manifest
  # path when the outer workspace doesn't list it as a member
  cargo run -q --manifest-path rust/xtask/Cargo.toml -- lint
fi

echo "== xtask self-tests (each rule catches its seeded fixture) =="
cargo test -q --manifest-path rust/xtask/Cargo.toml
if command -v python3 >/dev/null 2>&1; then
  # the python mirror must agree with the analyzer on the fixtures
  python3 scripts/lint_mirror.py --self-test
fi

echo "== cargo build --examples =="
cargo build --examples

echo "== cargo bench --bench runtime_hotpath --no-run =="
# bench code must keep compiling even on machines that never run it
cargo bench --bench runtime_hotpath --no-run

echo "== manifest schema (schema-3 geometry + param-column layout) =="
if command -v python3 >/dev/null 2>&1; then
  python3 scripts/check_manifest.py
else
  echo "WARNING: python3 not found — manifest-schema gate SKIPPED on this machine"
fi

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== robustness: fault-injection soak (32 runs) =="
# the §5.1 completion-rate claim under ≥10% injected transient faults;
# the schedule is seeded, so this size is exactly reproducible
WEBOTS_HPC_SOAK_RUNS=32 cargo test -q --release --test robustness

echo "== fabric: loopback coordinator/worker smoke =="
# distributed execution over real TCP: one hard worker kill, forced
# duplicate completions, 100% completion (full soak runs under tier-1)
cargo test -q --release --test fabric fabric_smoke

echo "== loom: exhaustive interleaving models (lease/registry/cache) =="
# needs the loom crate; without it the same invariants still ran above
# as real-thread stress tests inside tier-1 (tests/loom_models.rs)
if cargo metadata --format-version 1 2>/dev/null | grep -q '"name":"loom"'; then
  RUSTFLAGS="--cfg loom" cargo test -q --release --test loom_models
else
  echo "WARNING: loom crate not in the dependency graph — loom lane SKIPPED" \
       "(stress-test fallback already ran in tier-1)"
fi

echo "== sanitizers (opt-in: WEBOTS_HPC_TSAN=1 / WEBOTS_HPC_MIRI=1) =="
# ThreadSanitizer over the concurrency-heavy test targets.  Needs a
# nightly toolchain with rust-src; opt-in because a TSan run is ~10x
# slower than the plain suite.
if [ "${WEBOTS_HPC_TSAN:-0}" = "1" ]; then
  if rustup toolchain list 2>/dev/null | grep -q nightly; then
    RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
      cargo +nightly test -q -Zbuild-std --target x86_64-unknown-linux-gnu \
        --test loom_models --test telemetry --test fabric
  else
    echo "WARNING: WEBOTS_HPC_TSAN=1 but no nightly toolchain — TSan lane SKIPPED"
  fi
fi
# Miri over the lock-free metrics unit tests (UB + weak-memory checks).
if [ "${WEBOTS_HPC_MIRI:-0}" = "1" ]; then
  if command -v cargo-miri >/dev/null 2>&1 || rustup component list --toolchain nightly 2>/dev/null | grep -q 'miri.*(installed)'; then
    cargo +nightly miri test -q --lib telemetry::metrics
  else
    echo "WARNING: WEBOTS_HPC_MIRI=1 but miri not installed — miri lane SKIPPED"
  fi
fi

echo "check.sh: all gates passed"
