#!/usr/bin/env bash
# Pre-PR gate (EXPERIMENTS.md, ROADMAP.md): formatting, lints, and the
# tier-1 build/test command.  Run from anywhere inside the repo.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== control-plane lint gate (no unwrap/expect in pipeline/) =="
# the deny attribute is what clippy enforces; make sure nobody quietly
# removes it from the unattended-campaign control plane
grep -q 'deny(clippy::unwrap_used, clippy::expect_used)' rust/src/pipeline/mod.rs \
  || { echo "FAIL: pipeline/mod.rs lost its unwrap/expect deny gate"; exit 1; }
grep -q 'deny(clippy::unwrap_used, clippy::expect_used)' rust/src/fabric/mod.rs \
  || { echo "FAIL: fabric/mod.rs lost its unwrap/expect deny gate"; exit 1; }

echo "== telemetry lint gate (no println!/eprintln! in library code) =="
# library observability goes through telemetry::emit / the metrics
# registry; stray prints vanish in batch campaigns.  Test modules are
# exempt (everything after the first #[cfg(test)] in a file), and
# main.rs is the CLI — printing is its job.
print_gate_fail=0
while IFS= read -r f; do
  hits=$(awk '/#\[cfg\(test\)\]/{exit} /(println|eprintln)!/{print FILENAME ":" FNR ": " $0}' "$f")
  if [ -n "$hits" ]; then
    echo "$hits"
    print_gate_fail=1
  fi
done < <(find rust/src/runtime rust/src/pipeline rust/src/telemetry rust/src/fabric -name '*.rs')
[ "$print_gate_fail" -eq 0 ] \
  || { echo "FAIL: library code prints to stdout/stderr — emit telemetry events instead"; exit 1; }

echo "== cargo build --examples =="
cargo build --examples

echo "== cargo bench --bench runtime_hotpath --no-run =="
# bench code must keep compiling even on machines that never run it
cargo bench --bench runtime_hotpath --no-run

echo "== manifest schema (schema-3 geometry + param-column layout) =="
if command -v python3 >/dev/null 2>&1; then
  python3 scripts/check_manifest.py
else
  echo "WARNING: python3 not found — manifest-schema gate SKIPPED on this machine"
fi

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "== robustness: fault-injection soak (32 runs) =="
# the §5.1 completion-rate claim under ≥10% injected transient faults;
# the schedule is seeded, so this size is exactly reproducible
WEBOTS_HPC_SOAK_RUNS=32 cargo test -q --release --test robustness

echo "== fabric: loopback coordinator/worker smoke =="
# distributed execution over real TCP: one hard worker kill, forced
# duplicate completions, 100% completion (full soak runs under tier-1)
cargo test -q --release --test fabric fabric_smoke

echo "check.sh: all gates passed"
