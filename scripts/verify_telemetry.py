#!/usr/bin/env python3
"""Independent python mirror of the telemetry consumers (ISSUE 7).

Re-implements, from the documented formats alone (no rust parsing):

  * the Chrome/Perfetto trace-event conversion for the fixed golden
    stream behind `rust/tests/golden/chrome_trace.json`
  * the log2 histogram bucketing of `telemetry::metrics::Histogram`
  * the report aggregation (`telemetry::summarize`) for the golden
    stream

Default mode verifies all three against the committed golden and the
rust-side semantics; `--golden` rewrites the golden file instead (do
that only when a trace-format change is intentional — the rust test
`chrome_trace_export_matches_golden` byte-compares against it).

`--append-bench` measures the python-mirror stand-in for the rust
`hlo_rollout_telemetry_{off,on}` bench pair — the same jitted K=32
rollout dispatch, with and without a mirrored per-dispatch telemetry
cost (one histogram record + one event dict serialized to a JSONL
buffer) — and appends the pair to `BENCH_runtime_hotpath.json`
(EXPERIMENTS.md §Observability; re-measure with `cargo bench` on a
machine with the rust toolchain).

The byte-identity trick: `util::Json` serializes objects from a
BTreeMap (alphabetical keys) with a compact one-line form, which is
exactly `json.dumps(doc, sort_keys=True, separators=(",", ":"))` as
long as every number is an integer below 1e15.
"""

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "rust" / "tests" / "golden" / "chrome_trace.json"

ENGINE_PID = 99  # mirror of telemetry::trace::ENGINE_PID
HIST_BUCKETS = 64

# ---------------------------------------------------------------------------
# The fixed stream behind the golden trace (mirror of golden_events()
# in rust/tests/telemetry.rs): one run, a transient retry, a coalesced
# rollout dispatch, a ledger transition.

RUN = "golden-e0[0]"
GOLDEN_EVENTS = [
    {"ev": "run_begin", "t_us": 100, "run_id": RUN, "epoch": 0, "slot": 0, "node": 0},
    {"ev": "attempt_begin", "t_us": 110, "run_id": RUN, "attempt": 0, "engine": "hlo"},
    {"ev": "attempt_end", "t_us": 150, "run_id": RUN, "attempt": 0, "ok": False},
    {
        "ev": "retry",
        "t_us": 160,
        "run_id": RUN,
        "attempt": 0,
        "class": "transient",
        "error": "TraCI port 8873 already in use",
        "backoff_ms": 5,
    },
    {"ev": "attempt_begin", "t_us": 170, "run_id": RUN, "attempt": 1, "engine": "hlo"},
    {
        "ev": "dispatch_end",
        "t_us": 300,
        "kind": "rollout",
        "bucket": 64,
        "k": 32,
        "batch": 2,
        "dur_us": 40,
    },
    {"ev": "attempt_end", "t_us": 400, "run_id": RUN, "attempt": 1, "ok": True},
    {"ev": "ledger_transition", "t_us": 410, "run_id": RUN, "state": "completed"},
    {
        "ev": "run_end",
        "t_us": 420,
        "run_id": RUN,
        "ok": True,
        "attempts": 2,
        "degraded": False,
    },
]


# ---------------------------------------------------------------------------
# Mirror of telemetry::trace::to_chrome_trace


def span(name, cat, ts, dur, pid, tid, args):
    return {
        "name": name,
        "cat": cat,
        "ph": "X",
        "ts": ts,
        "dur": dur,
        "pid": pid,
        "tid": tid,
        "args": args,
    }


def instant(name, cat, ts, pid, tid, args):
    return {
        "name": name,
        "cat": cat,
        "ph": "i",
        "s": "t",
        "ts": ts,
        "pid": pid,
        "tid": tid,
        "args": args,
    }


def metadata(name, pid, tid, label):
    row = {"name": name, "ph": "M", "pid": pid, "args": {"name": label}}
    if tid is not None:
        row["tid"] = tid
    return row


def to_chrome_trace(events):
    runs_open = {}  # run_id -> (node, slot, t0)
    lanes = {}  # run_id -> (node, slot)
    attempts_open = {}  # (run_id, attempt) -> (t0, engine)
    out = []
    for ev in events:
        tag, t = ev["ev"], ev["t_us"]
        if tag == "run_begin":
            runs_open[ev["run_id"]] = (ev["node"], ev["slot"], t)
            lanes[ev["run_id"]] = (ev["node"], ev["slot"])
        elif tag == "run_end":
            if ev["run_id"] in runs_open:
                node, slot, t0 = runs_open.pop(ev["run_id"])
                out.append(
                    span(
                        ev["run_id"],
                        "run",
                        t0,
                        max(t - t0, 0),
                        node,
                        slot,
                        {
                            "ok": ev["ok"],
                            "attempts": ev["attempts"],
                            "degraded": ev["degraded"],
                        },
                    )
                )
        elif tag == "attempt_begin":
            attempts_open[(ev["run_id"], ev["attempt"])] = (t, ev["engine"])
        elif tag == "attempt_end":
            key = (ev["run_id"], ev["attempt"])
            if key in attempts_open:
                t0, engine = attempts_open.pop(key)
                node, slot = lanes.get(ev["run_id"], (0, 0))
                out.append(
                    span(
                        f"attempt {ev['attempt']}",
                        "attempt",
                        t0,
                        max(t - t0, 0),
                        node,
                        slot,
                        {"engine": engine, "ok": ev["ok"]},
                    )
                )
        elif tag == "dispatch_end":
            name = (
                f"{ev['kind']} K={ev['k']} N={ev['bucket']}"
                if ev["k"] > 0
                else f"{ev['kind']} N={ev['bucket']}"
            )
            out.append(
                span(
                    name,
                    "dispatch",
                    max(t - ev["dur_us"], 0),
                    ev["dur_us"],
                    ENGINE_PID,
                    ev["k"],
                    {"batch": ev["batch"]},
                )
            )
        elif tag == "retry":
            node, slot = lanes.get(ev["run_id"], (0, 0))
            out.append(
                instant(
                    f"retry ({ev['class']})",
                    "retry",
                    t,
                    node,
                    slot,
                    {
                        "run_id": ev["run_id"],
                        "attempt": ev["attempt"],
                        "backoff_ms": ev["backoff_ms"],
                    },
                )
            )
        elif tag == "watchdog_fire":
            node, slot = lanes.get(ev["run_id"], (0, 0))
            out.append(
                instant(
                    f"watchdog ({ev['kind']})",
                    "watchdog",
                    t,
                    node,
                    slot,
                    {"run_id": ev["run_id"], "detail": ev["detail"]},
                )
            )
        elif tag == "degraded":
            node, slot = lanes.get(ev["run_id"], (0, 0))
            out.append(
                instant(
                    "degraded to native",
                    "degrade",
                    t,
                    node,
                    slot,
                    {"run_id": ev["run_id"], "attempt": ev["attempt"]},
                )
            )
        elif tag == "ledger_transition":
            node, slot = lanes.get(ev["run_id"], (0, 0))
            out.append(
                instant(
                    f"ledger: {ev['state']}",
                    "ledger",
                    t,
                    node,
                    slot,
                    {"run_id": ev["run_id"]},
                )
            )
        # campaign/slot bookkeeping, dispatch begins and batcher details
        # don't need their own trace rows

    meta = []
    for node in sorted({n for n, _ in lanes.values()}):
        meta.append(metadata("process_name", node, None, f"node {node}"))
    for node, slot in sorted(set(lanes.values())):
        meta.append(metadata("thread_name", node, slot, f"slot {slot}"))
    if any(ev["ev"] == "dispatch_end" for ev in events):
        meta.append(metadata("process_name", ENGINE_PID, None, "engine"))
    return {"displayTimeUnit": "ms", "traceEvents": meta + out}


def dumps(doc):
    # byte-identical to util::Json::to_compact_string (BTreeMap order)
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


# ---------------------------------------------------------------------------
# Mirror of telemetry::metrics::Histogram bucketing


def bucket_index(v):
    return 0 if v == 0 else min(v.bit_length(), HIST_BUCKETS - 1)


def bucket_edge(i):
    if i == 0:
        return 0
    if i >= HIST_BUCKETS - 1:
        return 2**64 - 1
    return (1 << i) - 1


# ---------------------------------------------------------------------------
# Mirror of telemetry::report::summarize for the golden stream


def summarize(events):
    begun, completed, failed = set(), set(), set()
    latest = {}
    attempts = retries_total = backoff = 0
    retries = {}
    dispatch = {}
    for ev in events:
        tag = ev["ev"]
        if tag in ("run_begin", "ledger_transition"):
            begun.add(ev["run_id"])
        if tag == "ledger_transition":
            latest[ev["run_id"]] = ev["state"]
        elif tag == "attempt_begin":
            attempts += 1
        elif tag == "retry":
            retries_total += 1
            retries[ev["class"]] = retries.get(ev["class"], 0) + 1
            backoff += ev["backoff_ms"]
        elif tag == "dispatch_end":
            key = (ev["kind"], ev["k"])
            count, batched = dispatch.get(key, (0, 0))
            dispatch[key] = (count + 1, batched + (1 if ev["batch"] > 1 else 0))
    for run_id, state in latest.items():
        (completed if state == "completed" else failed).add(run_id)
    return {
        "runs_seen": len(begun),
        "completed": len(completed),
        "failed": len(failed),
        "attempts": attempts,
        "retries": retries,
        "retries_total": retries_total,
        "backoff_ms_total": backoff,
        "dispatch": dispatch,
    }


# ---------------------------------------------------------------------------


def verify():
    failures = []

    # 1. golden byte-compare
    want = dumps(to_chrome_trace(GOLDEN_EVENTS))
    have = GOLDEN.read_text().rstrip("\n")
    if want != have:
        failures.append(
            f"golden trace drifted: mirror produced {len(want)}B, "
            f"{GOLDEN} holds {len(have)}B (run with --golden to accept)"
        )
    else:
        print(f"OK golden trace byte-identical ({len(want)} bytes, {GOLDEN.name})")

    # 2. histogram bucketing mirror (the metrics.rs unit-test vectors +
    #    edge/index round-trip over every bucket)
    vectors = [(0, 0), (1, 1), (2, 2), (3, 2), (4, 3), (1023, 10), (1024, 11), (2**64 - 1, 63)]
    for v, idx in vectors:
        if bucket_index(v) != idx:
            failures.append(f"bucket_index({v}) = {bucket_index(v)}, want {idx}")
    for i in range(HIST_BUCKETS):
        if bucket_index(bucket_edge(i)) != i:
            failures.append(f"bucket_edge({i}) does not map back to bucket {i}")
    if not failures:
        print(f"OK histogram bucketing ({len(vectors)} vectors, {HIST_BUCKETS} edges)")

    # 3. report aggregation for the golden stream
    rep = summarize(GOLDEN_EVENTS)
    expect = {
        "runs_seen": 1,
        "completed": 1,
        "failed": 0,
        "attempts": 2,
        "retries": {"transient": 1},
        "retries_total": 1,
        "backoff_ms_total": 5,
        "dispatch": {("rollout", 32): (1, 1)},
    }
    if rep != expect:
        failures.append(f"golden-stream report mismatch:\n  got  {rep}\n  want {expect}")
    else:
        print("OK golden-stream report aggregation")

    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


# ---------------------------------------------------------------------------
# Python-mirror overhead bench for the hlo_rollout_telemetry_{off,on}
# rust pair (EXPERIMENTS.md §Observability)


def bench_overhead(append):
    import time

    sys.path.insert(0, str(REPO / "scripts"))
    sys.path.insert(0, str(REPO / "python"))
    try:
        import jax
        import jax.numpy as jnp
        import numpy as np
        import validate_sweep as vs
        from compile import model
    except ImportError as e:
        print(f"overhead bench skipped (no jax here: {e})")
        return 0

    k, n = 32, 64
    geometry = vs.FAMILY_GEOMETRIES["lane-drop-hi"]
    rng = np.random.default_rng(123)
    x, v, lane, act, params = vs.geometry_traffic(rng, n, geometry, True, exit_frac=0.25)
    state = jnp.stack(
        [jnp.asarray(x), jnp.asarray(v), jnp.asarray(lane), jnp.asarray(act.astype(vs.F))],
        axis=1,
    )
    pj = jnp.asarray(params)
    g = jnp.asarray(np.array(geometry, dtype=vs.F))
    fn = jax.jit(lambda s, p, gg: model.rollout_geom(s, p, gg, k))
    fn(state, pj, g)[0].block_until_ready()

    # telemetry on mirrors what the rust engine pays per dispatch: one
    # histogram record (bucket index + counter bump) and one guarded
    # DispatchEnd emit (event dict -> compact JSON line into a memory
    # buffer; the rust JsonlSink is buffered too).  The two variants
    # run as interleaved blocks so drift hits both equally — the
    # telemetry cost is microseconds against a multi-ms dispatch, so a
    # sequential A-then-B measurement is pure run-order noise.
    hist = [0] * HIST_BUCKETS
    hist_count = hist_sum = 0
    sink = []
    enabled = True
    block, blocks = 20, 10
    reps = block * blocks
    t_off = t_on = 0.0
    for _ in range(blocks):
        t0 = time.perf_counter()
        for _ in range(block):
            fn(state, pj, g)[0].block_until_ready()
        t_off += time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(block):
            d0 = time.perf_counter_ns()
            fn(state, pj, g)[0].block_until_ready()
            dur_us = (time.perf_counter_ns() - d0) // 1000
            hist[bucket_index(dur_us)] += 1
            hist_count += 1
            hist_sum += dur_us
            if enabled:
                sink.append(
                    dumps(
                        {
                            "ev": "dispatch_end",
                            "t_us": dur_us,
                            "kind": "rollout",
                            "bucket": n,
                            "k": k,
                            "batch": 1,
                            "dur_us": dur_us,
                        }
                    )
                )
        t_on += time.perf_counter() - t0
    sec_off = t_off / reps
    sec_on = t_on / reps
    assert hist_count == reps and len(sink) == reps

    overhead = (sec_on / sec_off - 1.0) * 100.0
    print(
        f"K={k} N={n}: off {sec_off * 1e3:.3f} ms/dispatch, "
        f"on {sec_on * 1e3:.3f} ms/dispatch -> {overhead:+.2f}% (budget 2%)"
    )
    if not append:
        return 0

    path = REPO / "BENCH_runtime_hotpath.json"
    doc = json.loads(path.read_text())
    doc["runs"].append(
        {
            "label": (
                "post-PR7-python-mirror (telemetry overhead on the fused K=32 "
                "rollout dispatch: one mirrored histogram record + one "
                "DispatchEnd event serialized to a buffered JSONL sink per "
                "dispatch, vs the bare dispatch — the "
                "hlo_rollout_telemetry_{off,on} rust pair)"
            ),
            "unix_time": int(time.time()),
            "source": "scripts/verify_telemetry.py",
            "results": [
                {
                    "name": f"mirror_hlo_rollout_telemetry_{tag}/K={k}/N={n}",
                    "ns_per_iter": int(sec * 1e9),
                    "iters": reps,
                    "steps_per_s": round(k / sec, 1),
                }
                for tag, sec in (("off", sec_off), ("on", sec_on))
            ],
        }
    )
    path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"appended telemetry-overhead pair to {path}")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--golden",
        action="store_true",
        help=f"rewrite {GOLDEN} from the mirror instead of verifying",
    )
    ap.add_argument(
        "--append-bench",
        action="store_true",
        help="measure the telemetry-overhead mirror pair and append it "
        "to BENCH_runtime_hotpath.json",
    )
    args = ap.parse_args()
    if args.golden:
        GOLDEN.parent.mkdir(parents=True, exist_ok=True)
        GOLDEN.write_text(dumps(to_chrome_trace(GOLDEN_EVENTS)) + "\n")
        print(f"wrote {GOLDEN}")
        return 0
    if args.append_bench:
        return bench_overhead(append=True)
    return verify()


if __name__ == "__main__":
    sys.exit(main())
