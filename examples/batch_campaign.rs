//! END-TO-END DRIVER — the validation run recorded in EXPERIMENTS.md.
//!
//! Exercises every layer of the stack on a real small workload, proving
//! they compose:
//!
//! **Part 1 (physics fidelity)**: a miniature cluster campaign with REAL
//! instances — 3 virtual nodes × 4 slots × 2 epochs = 24 runs of the
//! CAV highway-merge simulation, each with its own duarouter seed,
//! TraCI TCP server on a unique port, Xvfb display, Webots front-end
//! with the merge-assist controller, and physics on the AOT JAX/Pallas
//! artifact via PJRT.  Reports throughput, completion rate, per-node
//! distribution, and the aggregated output dataset.
//!
//! **Part 2 (scale fidelity)**: the paper's full 12-hour, 6-node × 8-slot
//! campaign in virtual time — Table 5.1 / Fig 5.1 regenerated, speedup
//! vs the personal-computer baseline.
//!
//! ```text
//! make artifacts && cargo run --release --example batch_campaign
//! ```

use webots_hpc::harness;
use webots_hpc::output::CampaignDataset;
use webots_hpc::pbs::script::appendix_b_script;
use webots_hpc::pipeline::{
    launch_node_slots, propagate_copies, ChunkSteps, InstanceConfig, PhysicsEngine, PortAllocator,
};
use webots_hpc::runtime::EngineService;
use webots_hpc::sumo::{FlowFile, MergeScenario};
use webots_hpc::webots::nodes::sample_merge_world;

const NODES: usize = 3;
const SLOTS: u16 = 4;
const EPOCHS: u64 = 2;
const HORIZON_S: f32 = 60.0;

fn main() -> anyhow::Result<()> {
    println!("=== Webots.HPC end-to-end validation ===\n");
    println!("PBS job script (paper Appendix B):\n{}", appendix_b_script());

    // ---- Part 1: physics-fidelity mini-campaign -------------------------
    let physics = match EngineService::auto() {
        Ok(e) => {
            println!(
                "physics engine: AOT JAX/Pallas step via PJRT ({}), buckets {:?}",
                e.platform(),
                e.manifest().buckets
            );
            PhysicsEngine::Hlo(e)
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); using native physics");
            PhysicsEngine::Native
        }
    };

    let t0 = std::time::Instant::now();
    let mut dataset = CampaignDataset::new();
    let mut submitted = 0u64;
    let mut completed = 0u64;

    for epoch in 0..EPOCHS {
        // each epoch: every node runs `SLOTS` parallel instances
        for node in 0..NODES {
            let base = std::net::TcpListener::bind("127.0.0.1:0")?
                .local_addr()?
                .port();
            let root = sample_merge_world(base);
            let copies = propagate_copies(&root, SLOTS, &PortAllocator::new(base, 7))?;
            let configs: Vec<InstanceConfig> = copies
                .into_iter()
                .map(|c| InstanceConfig {
                    run_id: format!("{epoch}[{}]", node as u16 * SLOTS + c.index),
                    node,
                    world: c.world,
                    flows: FlowFile::merge_sample(1200.0, 300.0, HORIZON_S),
                    scenario: MergeScenario::default(),
                    seed: epoch * 1000 + (node as u64) * 100 + c.index as u64,
                    capacity: 64,
                    horizon_s: HORIZON_S,
                    max_steps: 2_000,
                    scenario_run: None,
                    chunk_steps: ChunkSteps::Auto,
                    faults: None,
                    watchdog: Default::default(),
                })
                .collect();
            submitted += configs.len() as u64;
            for r in launch_node_slots(configs, &physics) {
                match r {
                    Ok(ok) => {
                        completed += 1;
                        dataset.add(ok.dataset);
                    }
                    Err(e) => println!("instance failed: {e}"),
                }
            }
        }
        println!(
            "epoch {epoch}: cumulative {completed}/{submitted} runs complete"
        );
    }
    let wall = t0.elapsed();

    println!("\n--- Part 1 results (REAL instances) ---");
    println!(
        "completed {completed}/{submitted} runs ({:.1}% completion; paper claims 100%)",
        100.0 * completed as f64 / submitted as f64
    );
    println!("wall time: {:.2} s for {} simulated-seconds of traffic", wall.as_secs_f64(), completed as f32 * HORIZON_S);
    println!("runs per node: {:?}", dataset.runs_per_node(NODES));
    println!(
        "aggregate dataset: {} runs, {} rows, {} bytes, seeds unique: {}",
        dataset.num_runs(),
        dataset.total_rows(),
        dataset.total_bytes(),
        dataset.seeds_unique()
    );
    let (mean_flow, sd_flow) = dataset.flow_stats();
    println!("per-run throughput: {mean_flow:.1} ± {sd_flow:.1} vehicles");
    assert_eq!(completed, submitted, "E2E: every run must complete");
    assert!(dataset.seeds_unique());

    // ---- Part 2: the paper's 12-hour campaign in virtual time -----------
    println!("\n--- Part 2: paper-scale campaign (virtual time) ---\n");
    let t51 = harness::table_5_1()?;
    println!("{}", t51.render());
    println!("{}", harness::distribution_5_2()?.render());

    println!("=== end-to-end validation complete ===");
    Ok(())
}
