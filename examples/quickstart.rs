//! Quickstart: run ONE headless Webots-SUMO merge simulation through the
//! whole pipeline — container env, Xvfb display, TraCI server, Webots
//! front-end, output dataset — in a few lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the native rust physics engine so it works before
//! `make artifacts`; see `highway_merge` for the AOT/PJRT path.

use webots_hpc::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use webots_hpc::display::DisplayRegistry;
use webots_hpc::pipeline::{launch_instance, ChunkSteps, InstanceConfig, PhysicsEngine};
use webots_hpc::sumo::{FlowFile, MergeScenario};
use webots_hpc::webots::nodes::sample_merge_world;

fn main() -> anyhow::Result<()> {
    // a free port for this demo instance's TraCI server
    let port = std::net::TcpListener::bind("127.0.0.1:0")?
        .local_addr()?
        .port();

    // the .wbt world: WorldInfo + SumoInterface(port) + a CAV robot with
    // radar/GPS running the merge_assist controller
    let world = sample_merge_world(port);
    println!("--- world file (SIM_0.wbt) ---\n{}", world.render());

    let cfg = InstanceConfig {
        run_id: "quickstart[0]".into(),
        node: 0,
        world,
        flows: FlowFile::merge_sample(1200.0, 300.0, 60.0),
        scenario: MergeScenario::default(),
        seed: 42,
        capacity: 64,
        horizon_s: 60.0,
        max_steps: 1_000,
        scenario_run: None,
        chunk_steps: ChunkSteps::Auto,
        faults: None,
        watchdog: Default::default(),
    };

    // the container image the paper ships: official Webots docker image
    // + pip + numpy/pandas, converted to a Singularity SIF
    let sif = build_webots_hpc_image(BuildHost::PersonalComputer)?;
    println!("container image: {} (from {})", sif.name, sif.built_from);

    let env = ExecEnv::new(sif).bind("/tmp", "/tmp");
    let displays = DisplayRegistry::new();

    let result = launch_instance(&cfg, &displays, &env, &PhysicsEngine::Native)?;
    println!(
        "ran {} steps on display :{} port {}",
        result.steps, result.display, result.port
    );
    println!(
        "spawned {} vehicles, {} finished, {} merged, {} controller commands",
        result.dataset.total_spawned,
        result.dataset.total_flow,
        result.dataset.total_merged,
        result.controller_cmds
    );
    println!(
        "output dataset: {} rows (~{} bytes as CSV)",
        result.dataset.rows.len(),
        result.dataset.size_bytes()
    );
    println!("--- first 5 rows ---");
    for line in result.dataset.to_csv().lines().take(6) {
        println!("{line}");
    }
    Ok(())
}
