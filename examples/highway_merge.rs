//! The Phase-II scenario: the CAV highway-merge study on the AOT
//! JAX/Pallas physics (PJRT), sweeping demand levels and seeds.
//!
//! ```text
//! make artifacts && cargo run --release --example highway_merge
//! ```
//!
//! For each (mainline demand, ramp demand) cell the example runs several
//! seeded instances in parallel — exactly how the pipeline's "sources of
//! randomization" produce a dataset with per-run diversity — and reports
//! merge success statistics, the quantity Phase III would feed to an ML
//! model.

use webots_hpc::output::{mean, stddev, CampaignDataset};
use webots_hpc::pipeline::{
    launch_node_slots, propagate_copies, ChunkSteps, InstanceConfig, PhysicsEngine, PortAllocator,
};
use webots_hpc::runtime::EngineService;
use webots_hpc::sumo::{FlowFile, MergeScenario};
use webots_hpc::webots::nodes::sample_merge_world;

fn main() -> anyhow::Result<()> {
    let engine = match EngineService::auto() {
        Ok(e) => {
            println!("physics: AOT JAX/Pallas via PJRT ({})", e.platform());
            PhysicsEngine::Hlo(e)
        }
        Err(e) => {
            println!("artifacts unavailable ({e}); falling back to native physics");
            PhysicsEngine::Native
        }
    };

    const SEEDS_PER_CELL: u16 = 4;
    const HORIZON_S: f32 = 60.0;
    let demand_grid = [(800.0f32, 200.0f32), (1200.0, 300.0), (1800.0, 450.0)];

    println!(
        "\n{:>10} {:>8} | {:>8} {:>8} {:>10} {:>10}",
        "main vph", "ramp vph", "runs", "spawned", "merged/run", "flow/run"
    );
    println!("{}", "-".repeat(64));

    for (main_vph, ramp_vph) in demand_grid {
        // one node's worth of parallel instances, each with its own seed,
        // port and display
        let base = std::net::TcpListener::bind("127.0.0.1:0")?
            .local_addr()?
            .port();
        let root = sample_merge_world(base);
        let copies = propagate_copies(&root, SEEDS_PER_CELL, &PortAllocator::new(base, 7))?;
        let configs: Vec<InstanceConfig> = copies
            .into_iter()
            .map(|c| InstanceConfig {
                run_id: format!("merge[{}@{}]", c.index, main_vph),
                node: 0,
                world: c.world,
                flows: FlowFile::merge_sample(main_vph, ramp_vph, HORIZON_S),
                scenario: MergeScenario::default(),
                seed: 1000 + c.index as u64,
                capacity: 64,
                horizon_s: HORIZON_S,
                max_steps: 2_000,
                scenario_run: None,
                chunk_steps: ChunkSteps::Auto,
                faults: None,
                watchdog: Default::default(),
            })
            .collect();

        let results = launch_node_slots(configs, &engine);
        let mut ds = CampaignDataset::new();
        for r in results {
            ds.add(r?.dataset);
        }
        let merged: Vec<f64> = ds.runs.iter().map(|r| r.total_merged as f64).collect();
        let flows: Vec<f64> = ds.runs.iter().map(|r| r.total_flow as f64).collect();
        let spawned: u64 = ds.runs.iter().map(|r| r.total_spawned).sum();
        println!(
            "{main_vph:>10.0} {ramp_vph:>8.0} | {:>8} {spawned:>8} {:>7.1}±{:<4.1} {:>7.1}±{:<4.1}",
            ds.num_runs(),
            mean(&merged),
            stddev(&merged),
            mean(&flows),
            stddev(&flows),
        );
        assert!(ds.seeds_unique(), "every run must have its own seed");
    }

    println!("\neach cell = {SEEDS_PER_CELL} parallel instances (unique TraCI ports + Xvfb displays)");
    Ok(())
}
