//! Platooning — the pipeline as "a platform for new robotics simulation
//! endeavors" (paper §1.1 / related work [13], vehicular platoons in
//! Webots).  A CACC controller regulates constant distance-gaps down a
//! platoon using the forward radar; the run reports gap convergence —
//! a completely different workload on the unchanged pipeline.
//!
//! ```text
//! cargo run --release --example platoon
//! ```

use webots_hpc::sumo::{duarouter, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
use webots_hpc::traci::{TraciClient, TraciServer};
use webots_hpc::webots::nodes::{RobotNode, SensorSpec, SumoInterface, WorldInfo};
use webots_hpc::webots::{StopCondition, WebotsSim, World};

/// A platoon world: same scene tree, `platoon` controller instead of
/// `merge_assist`.
fn platoon_world(port: u16) -> World {
    let mut w = World::new();
    w.nodes.push(
        WorldInfo {
            basic_time_step_ms: 100,
            optimal_thread_count: 10,
        }
        .to_node(),
    );
    w.nodes.push(
        SumoInterface {
            port,
            sampling_period_ms: 200,
        }
        .to_node(),
    );
    w.nodes.push(
        RobotNode {
            name: "platoon_supervisor".into(),
            controller: "platoon".into(),
            sensors: vec![SensorSpec::Radar { max_range: 150.0 }],
        }
        .to_node(),
    );
    w
}

fn main() -> anyhow::Result<()> {
    let port = std::net::TcpListener::bind("127.0.0.1:0")?
        .local_addr()?
        .port();

    // demand: a single-lane stream on the platoon lane (lane 1), no ramp
    let scenario = MergeScenario::default();
    let mut flows = FlowFile::merge_sample(900.0, 0.0, 120.0);
    flows.flows.retain(|f| f.id == "main_l1");
    // dense arrivals (one per ~2 s) so a platoon actually forms on the
    // 1 km road before vehicles retire
    flows.flows[0].vehs_per_hour = 3600.0;
    let routes = duarouter(&scenario.network(), &flows, 42)?;
    let server = TraciServer::spawn(
        port,
        SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default())),
    )?;

    let world = platoon_world(port);
    let mut sim = WebotsSim::open(&world)?.with_stop_condition(StopCondition::SimTime(90.0));

    // per-pair convergence: identify adjacent platoon pairs by SLOT at
    // t=22 s, re-measure the SAME pairs at t=34 s — CACC must have
    // shrunk every surviving too-wide gap
    sim.run(150)?; // t = 15 s
    let snap1 = sim_state(&mut sim)?;
    sim.run(150)?; // t = 30 s
    let snap2 = sim_state(&mut sim)?;
    println!(
        "simulated {:.0} s, {} CACC commands issued",
        sim.time_s(),
        sim.controller_cmds()
    );
    sim.close()?;
    server.join()?;

    let pairs = adjacent_pairs(&snap1);
    let mut before = Vec::new();
    let mut after = Vec::new();
    for &(follower, leader) in &pairs {
        if active(&snap2, follower) && active(&snap2, leader) {
            before.push(x(&snap1, leader) - x(&snap1, follower));
            after.push(x(&snap2, leader) - x(&snap2, follower));
        }
    }
    let mean = |g: &[f32]| g.iter().sum::<f32>() / g.len().max(1) as f32;
    println!("tracked pairs (same slots, 15 s apart): {}", before.len());
    println!("  gaps t=15s: mean {:.1} m", mean(&before));
    println!("  gaps t=30s: mean {:.1} m", mean(&after));
    println!("CACC target: 12 m + 4.5 m vehicle length = 16.5 m center-to-center");
    assert!(!before.is_empty(), "need surviving pairs to compare");
    // CACC compresses front-to-back: the pair directly behind the
    // cruising platoon leader must have closed hard (follower commanded
    // +5 m/s over the leader's 25 m/s cruise)
    let front_before = *before.last().expect("non-empty");
    let front_after = *after.last().expect("non-empty");
    println!(
        "  front pair: {front_before:.1} m -> {front_after:.1} m (leader cruises, follower closes)"
    );
    // actuation is SetSpeed at the 5 Hz sampling period against IDM
    // physics between samples (heterogeneous driver v0 fights the
    // command), so convergence is gradual — but the front pair must
    // measurably compress toward the target
    assert!(
        front_after < front_before - 5.0 || front_after < 20.0,
        "front pair must compress: {front_before:.1} -> {front_after:.1}"
    );
    // no pair should have collapsed below a safe bound
    assert!(after.iter().all(|&g| g > 5.0), "no collisions");
    Ok(())
}

fn active(state: &[f32], slot: usize) -> bool {
    state[slot * 4 + 3] > 0.5 && state[slot * 4 + 2] == 1.0
}

fn x(state: &[f32], slot: usize) -> f32 {
    state[slot * 4]
}

/// (follower_slot, leader_slot) for adjacent active lane-1 vehicles.
fn adjacent_pairs(state: &[f32]) -> Vec<(usize, usize)> {
    let mut v: Vec<(f32, usize)> = (0..state.len() / 4)
        .filter(|&i| active(state, i))
        .map(|i| (x(state, i), i))
        .collect();
    v.sort_by(|a, b| a.0.total_cmp(&b.0));
    v.windows(2).map(|w| (w[0].1, w[1].1)).collect()
}

/// State snapshot through the live TraCI session.
fn sim_state(sim: &mut WebotsSim) -> anyhow::Result<Vec<f32>> {
    Ok(sim.state_snapshot()?)
}

// the probe also works out-of-session via a raw client
#[allow(dead_code)]
fn alt_probe(port: u16) -> anyhow::Result<Vec<f32>> {
    let mut c = TraciClient::connect(port)?;
    Ok(c.get_state()?)
}
