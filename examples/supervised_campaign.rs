//! SUPERVISED CAMPAIGN DEMO — the robustness story, end to end.
//!
//! Runs a small campaign (2 nodes × 4 slots × 2 epochs = 16 runs)
//! through the full supervision stack while a seeded fault plan injects
//! transient failures at ~15% per site per attempt: duarouter exits,
//! display/port races, and mid-run panics.  The supervisor contains
//! every one (catch_unwind, taxonomy, bounded retry with seeded
//! backoff), the crash-safe ledger records every transition, and the
//! final accounting shows the retry bill behind the 100% completion
//! rate — the §5.1 claim, demonstrated rather than asserted.
//!
//! Re-running with the same `--ledger` directory resumes: completed
//! runs are skipped, the aggregate is rebuilt identically.
//!
//! ```text
//! cargo run --release --example supervised_campaign
//! ```

use webots_hpc::pipeline::{
    run_supervised_campaign, FaultPlan, PhysicsEngine, RetryPolicy, SupervisedCampaignSpec,
    SupervisorSpec,
};
use webots_hpc::util::TempDir;
use webots_hpc::webots::WatchdogSpec;

fn main() -> webots_hpc::Result<()> {
    let ledger_dir = TempDir::new("supervised-campaign")?;
    let spec = SupervisedCampaignSpec {
        name: "demo".into(),
        nodes: 2,
        slots_per_node: 4,
        epochs: 2,
        horizon_s: 10.0,
        capacity: 64,
        seed: 2021,
        matrix: None,
        supervisor: SupervisorSpec {
            retry: RetryPolicy {
                max_attempts: 8,
                base_ms: 10,
                cap_ms: 200,
            },
            watchdog: WatchdogSpec::default(),
            degrade: true,
            fault_plan: Some(FaultPlan::transient_only(99, 0.15)),
        },
        ledger_dir: ledger_dir.path().to_path_buf(),
        retry_failed: false,
        stop_after_runs: None,
    };

    println!(
        "supervised campaign: {} nodes x {} slots x {} epochs = {} runs",
        spec.nodes,
        spec.slots_per_node,
        spec.epochs,
        spec.total_runs()
    );
    println!("fault plan: seed 99, 15% transient faults per site per attempt\n");

    let outcome = run_supervised_campaign(&spec, &PhysicsEngine::Native)?;

    for report in &outcome.reports {
        if report.failures.is_empty() {
            continue;
        }
        println!("run {} took {} attempts:", report.run_id, report.attempts);
        for f in &report.failures {
            println!(
                "  attempt {}: [{}] {} (backoff {}ms)",
                f.attempt,
                f.class.name(),
                f.error,
                f.backoff_ms
            );
        }
    }

    let stats = outcome
        .result
        .robustness
        .expect("supervised campaigns always report robustness accounting");
    println!("\naccounting:");
    println!("  runs            : {}", stats.runs);
    println!("  completed       : {}", stats.completed);
    println!("  failed          : {}", stats.failed);
    println!("  attempts        : {}", stats.attempts);
    println!("  retries         : {}", stats.retries);
    println!("  degraded        : {}", stats.degraded);
    println!("  walltime kills  : {}", stats.killed_walltime);
    println!("  stall kills     : {}", stats.killed_stall);
    println!(
        "  completion rate : {:.1}% (paper §5.1: \"100% simulation completion rate\")",
        100.0 * stats.completion_rate()
    );
    println!(
        "\naggregate: {} runs, {} rows, run_ids unique: {}",
        outcome.dataset.num_runs(),
        outcome.dataset.total_rows(),
        outcome.dataset.run_ids_unique()
    );
    Ok(())
}
