//! GUI-enabled mode (§3.1.2): SSH into the cluster with `-X`, open a
//! forwarded X11 display, start Webots with the GUI streaming frames
//! back to the client.
//!
//! ```text
//! cargo run --release --example gui_session
//! ```

use webots_hpc::display::{DisplayRegistry, SshSession, X11Forward};
use webots_hpc::sumo::{duarouter, FlowFile, MergeScenario, NativeIdmStepper, SumoSim};
use webots_hpc::traci::TraciServer;
use webots_hpc::webots::nodes::sample_merge_world;
use webots_hpc::webots::{StopCondition, WebotsSim};

fn main() -> anyhow::Result<()> {
    let registry = DisplayRegistry::new();

    // the mistake first: ssh WITHOUT -X cannot forward X11 (§4.1.5)
    let plain = SshSession::connect("mfranchi", "login.palmetto.clemson.edu", false);
    match X11Forward::open(&plain, &registry) {
        Err(e) => println!("without -X: {e}"),
        Ok(_) => unreachable!("plain ssh must not forward X11"),
    }

    // now properly: ssh -X
    let session = SshSession::connect("mfranchi", "login.palmetto.clemson.edu", true);
    let mut forward = X11Forward::open(&session, &registry)?;
    println!(
        "ssh -X {}@{}: forwarded display :{}",
        session.user, session.host, forward.display.number
    );

    // boot the SUMO back-end + GUI-mode Webots on the forwarded display
    let port = std::net::TcpListener::bind("127.0.0.1:0")?
        .local_addr()?
        .port();
    let scenario = MergeScenario::default();
    let routes = duarouter(
        &scenario.network(),
        &FlowFile::merge_sample(1200.0, 300.0, 30.0),
        7,
    )?;
    // TraCI-attached live-GUI run: force K=1 chunks so every rendered
    // frame gets a fresh back-end step — a fused 32-step chunk would
    // starve the stream between dispatches
    let mut sumo = SumoSim::new(scenario, 64, routes, Box::new(NativeIdmStepper::default()));
    sumo.set_chunk_limit(1);
    let server = TraciServer::spawn(port, sumo)?;

    let world = sample_merge_world(port);
    let mut sim = WebotsSim::open(&world)?.with_stop_condition(StopCondition::SimTime(15.0));
    // GUI mode: every rendered step streams one frame over the forward
    while sim.step()?.n_active >= 0.0 {
        forward.stream_frame();
        if sim.time_s() >= 15.0 {
            break;
        }
    }
    println!(
        "simulated {:.1} s in GUI mode, streamed {} frames to the client",
        sim.time_s(),
        forward.frames_streamed
    );
    sim.close()?;
    server.join()?;
    Ok(())
}
