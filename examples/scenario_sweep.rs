//! Scenario-matrix smoke test: a 3-family × 4-point sweep end to end.
//!
//! ```text
//! cargo run --release --example scenario_sweep
//! ```
//!
//! Demonstrates the scenario subsystem across the whole stack: a
//! [`ScenarioMatrix`] over the three non-merge families (lane-drop
//! bottleneck, on/off-ramp weave, ring shockwave), Latin-hypercube
//! sampled, each point materialized **coordination-free** from `(seed,
//! run index)` and launched through the real instance path (container
//! env → Xvfb display → TraCI server → Webots front-end).  Physics runs
//! on the geometry-generic AOT/PJRT fast path when `make artifacts` has
//! been run (the schema-2 executables take each family's geometry as a
//! runtime operand), falling back to the native stepper otherwise.  The
//! aggregated dataset is ML-ready: every row carries its generating
//! `ScenarioId` + parameter vector, and the `scenarios` manifest
//! (util::Json) is the matching codebook.

use webots_hpc::container::{build_webots_hpc_image, BuildHost, ExecEnv};
use webots_hpc::display::DisplayRegistry;
use webots_hpc::output::CampaignDataset;
use webots_hpc::pipeline::{launch_instance, InstanceConfig, PhysicsEngine};
use webots_hpc::runtime::EngineService;
use webots_hpc::scenario::{
    scenarios_manifest, FamilyRegistry, SamplerKind, ScenarioMatrix,
};
use webots_hpc::sumo::steps_for;
use webots_hpc::webots::nodes::sample_merge_world;

const SAMPLES_PER_FAMILY: usize = 4;
/// Keep the smoke test quick: cap each run's simulated horizon [s].
const HORIZON_CAP_S: f32 = 40.0;

fn main() -> anyhow::Result<()> {
    // the geometry-generic, destination-aware artifacts serve every
    // family from one pooled executable per bucket; without artifacts
    // the sweep stays native
    let service = EngineService::auto().ok();
    // suggest capacities from the actually-lowered bucket ladder so
    // every point rides PJRT — zero native fallbacks
    let registry = match &service {
        Some(s) => FamilyRegistry::builtin().with_buckets(&s.manifest().buckets),
        None => FamilyRegistry::builtin(),
    };
    let matrix = ScenarioMatrix::new(
        vec![
            "lane-drop".into(),
            "ramp-weave".into(),
            "ring-shockwave".into(),
        ],
        SamplerKind::Lhs {
            strata: SAMPLES_PER_FAMILY,
        },
        SAMPLES_PER_FAMILY,
        42,
    );
    println!(
        "scenario matrix: {} families x {} points = {} runs (LHS, seed {})\n",
        matrix.families.len(),
        matrix.samples_per_family,
        matrix.total_points(),
        matrix.seed
    );

    let env = ExecEnv::new(build_webots_hpc_image(BuildHost::PersonalComputer)?).bind("/tmp", "/tmp");
    let displays = DisplayRegistry::new();
    let mut dataset = CampaignDataset::new();

    match &service {
        Some(s) => println!("physics: AOT/PJRT ({} platform)\n", s.platform()),
        None => println!("physics: native stepper (run `make artifacts` for PJRT)\n"),
    }

    for run_index in 0..matrix.total_points() {
        // each "array node" derives its own point from (seed, index)
        let planned = matrix.materialize(&registry, run_index)?;
        let port = std::net::TcpListener::bind("127.0.0.1:0")?
            .local_addr()?
            .port();
        let world = sample_merge_world(port);
        let mut cfg = InstanceConfig::from_planned(
            format!("sweep[{run_index}]"),
            run_index as usize % 3,
            world,
            &planned,
        );
        cfg.horizon_s = cfg.horizon_s.min(HORIZON_CAP_S);
        cfg.max_steps = steps_for(cfg.horizon_s, cfg.scenario.dt_s) + 100;

        // the registry suggests from the lowered ladder, so with
        // artifacts present every point rides PJRT
        let physics = match &service {
            Some(s) => {
                assert!(
                    s.manifest().buckets.contains(&cfg.capacity),
                    "capacity {} not lowered (buckets {:?})",
                    cfg.capacity,
                    s.manifest().buckets
                );
                PhysicsEngine::Hlo(s.clone())
            }
            None => PhysicsEngine::Native,
        };
        let result = launch_instance(&cfg, &displays, &env, &physics)?;
        println!(
            "{:<34} {:>4} rows  {:>3} spawned  {:>5.1} flow  params: {}",
            result.dataset.run_id,
            result.dataset.rows.len(),
            result.dataset.total_spawned,
            result.dataset.total_flow,
            planned
                .config
                .tag
                .params
                .iter()
                .take(3)
                .map(|(n, v)| format!("{n}={}", v.render()))
                .collect::<Vec<_>>()
                .join(" "),
        );
        dataset.add(result.dataset);
    }

    // --- the aggregate layer is self-describing --------------------------
    println!("\nruns per scenario: {:?}", dataset.runs_per_scenario());
    println!("parameter columns: {:?}", dataset.param_columns());
    let csv = dataset.to_ml_csv();
    println!("\n--- ML-ready dataset head ({} rows total) ---", dataset.total_rows());
    for line in csv.lines().take(4) {
        println!("{line}");
    }

    // every run is attributable to its generating point
    assert_eq!(dataset.num_runs() as u64, matrix.total_points());
    assert!(dataset.runs.iter().all(|r| r.scenario.is_some()));
    assert!(dataset.runs.iter().any(|r| r.total_spawned > 0));
    assert!(!dataset.param_columns().is_empty());
    assert!(dataset.seeds_unique());

    // --- the scenarios manifest (the dataset codebook) -------------------
    let manifest = scenarios_manifest(&registry, &matrix)?;
    let text = manifest.to_pretty_string();
    println!("\n--- scenarios manifest (first 24 lines) ---");
    for line in text.lines().take(24) {
        println!("{line}");
    }
    if let Some(s) = &service {
        // pooled-executable observability: misses stay bounded by the
        // number of (kernel, bucket) pairs even across mixed families
        if let Ok(usage) = s.pool_usage() {
            println!("\n{}", usage.render());
        }
    }
    println!("\nscenario sweep complete: {} runs aggregated", dataset.num_runs());
    Ok(())
}
